//! Pure-Rust propagator: the reference transformer as a Φ.
//!
//! With `rust/vendor/xla` as an offline stub this is the production hot
//! path for every solve, so it is built around buffer reuse:
//!
//! * `step_into` / `adjoint_step_into` write into caller-provided state
//!   tensors and route all temporaries through a pooled
//!   [`crate::reference::Scratch`] workspace — **zero heap allocations** at
//!   steady state (pinned by `rust/tests/alloc_audit.rs`);
//! * the stacked encoder-decoder state Z = [X, Y] is processed through
//!   slices of the state buffer directly (no split/join copies);
//! * per-layer θ lengths are cached at construction so `theta_len` never
//!   touches the params read-lock.
//!
//! Mirrors the stacked state handling of [`super::XlaPropagator`] exactly.

use std::sync::{Arc, Mutex, RwLock};

use super::propagator::{CacheUnsupported, Propagator, StepCounters};
use crate::config::{Arch, ModelConfig};
use crate::reference::{self, KvCache, RefDims, Scratch};
use crate::tensor::Tensor;

/// Shared per-layer flat parameters (the trainer mutates through this Arc).
///
/// v2: `Arc<RwLock<..>>` instead of `Rc<RefCell<..>>` so propagators are
/// `Send + Sync` and the threaded MGRIT backend can evaluate Φ from worker
/// threads. The training loop takes the write lock only inside the
/// optimizer update; all solves hold read locks.
pub type SharedParams = Arc<RwLock<Vec<Vec<f32>>>>;

/// Build a [`SharedParams`] from per-layer flat vectors.
pub fn shared_params(layers: Vec<Vec<f32>>) -> SharedParams {
    Arc::new(RwLock::new(layers))
}

/// Reference-transformer propagator over the MGRIT domain.
pub struct RustPropagator {
    dims: RefDims,
    arch: Arch,
    n_enc: usize,
    n_steps: usize,
    /// per-layer fine step sizes (buffer layers get Δt=1, Appendix B)
    hs: Vec<f32>,
    params: SharedParams,
    /// Cached per-layer θ lengths (avoids the params read-lock on
    /// `theta_len`, which MGRIT calls per layer per step).
    theta_lens: Vec<usize>,
    /// Pool of per-thread scratch workspaces: each Φ evaluation checks one
    /// out and returns it, so concurrent relaxation workers never share a
    /// workspace and the steady state allocates nothing. The Mutex costs
    /// two uncontended lock ops (~tens of ns) per Φ eval — noise next to a
    /// Φ application; revisit (thread-local workspaces) only if profiles
    /// ever show contention with large worker counts on tiny models.
    scratch: Mutex<Vec<Scratch>>,
    counters: StepCounters,
}

/// Per-layer fine h: buffer layers Δt=1, ParallelNet layers Δt=fine_h()
/// (paper Appendix B).
pub fn layer_hs(model: &ModelConfig, n_layers: usize) -> Vec<f32> {
    let h_mid = model.fine_h();
    (0..n_layers)
        .map(|l| {
            if l < model.buffer_open || l >= n_layers.saturating_sub(model.buffer_close) {
                1.0
            } else {
                h_mid
            }
        })
        .collect()
}

impl RustPropagator {
    /// `params[l]` is layer l's flat θ (enc layout, or dec layout past
    /// n_enc); uniform fine step `h` across all layers.
    pub fn new(model: &ModelConfig, h: f32, params: SharedParams) -> RustPropagator {
        let n = params.read().unwrap().len();
        Self::with_hs(model, vec![h; n], params)
    }

    /// Buffer-aware constructor: Δt per layer from [`layer_hs`].
    pub fn for_model(model: &ModelConfig, params: SharedParams) -> RustPropagator {
        let n = params.read().unwrap().len();
        Self::with_hs(model, layer_hs(model, n), params)
    }

    pub fn with_hs(model: &ModelConfig, hs: Vec<f32>, params: SharedParams) -> RustPropagator {
        let theta_lens: Vec<usize> = params.read().unwrap().iter().map(|t| t.len()).collect();
        let n_steps = theta_lens.len();
        assert_eq!(hs.len(), n_steps);
        RustPropagator {
            dims: RefDims {
                batch: model.batch,
                seq: model.seq,
                d_model: model.d_model,
                n_heads: model.n_heads,
                d_ff: model.d_ff,
            },
            arch: model.arch,
            n_enc: if model.arch == Arch::EncDec { model.n_enc_layers } else { 0 },
            n_steps,
            hs,
            params,
            theta_lens,
            scratch: Mutex::new(Vec::new()),
            counters: StepCounters::default(),
        }
    }

    /// Run `f` with a pooled scratch workspace (checked back in after).
    fn with_scratch<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        let mut s = self.scratch.lock().unwrap().pop().unwrap_or_default();
        let out = f(&mut s);
        self.scratch.lock().unwrap().push(s);
        out
    }

    /// One Φ application with the parameter lock already resolved to θ,
    /// operating on raw state slices (`out` fully overwritten). For the
    /// stacked EncDec state the two halves are processed in place — no
    /// split/join copies.
    fn apply_into(&self, layer: usize, theta: &[f32], h: f32, z: &[f32], out: &mut [f32]) {
        self.with_scratch(|s| match self.arch {
            Arch::Encoder => reference::enc_step_fwd_into(z, theta, h, &self.dims, false, out, s),
            Arch::Decoder => reference::enc_step_fwd_into(z, theta, h, &self.dims, true, out, s),
            Arch::EncDec => {
                let half = z.len() / 2;
                let (zx, zy) = z.split_at(half);
                let (ox, oy) = out.split_at_mut(half);
                if layer < self.n_enc {
                    reference::enc_step_fwd_into(zx, theta, h, &self.dims, false, ox, s);
                    oy.copy_from_slice(zy);
                } else {
                    let seq = self.dims.seq;
                    reference::dec_step_fwd_into(zy, zx, theta, h, &self.dims, seq, oy, s);
                    ox.copy_from_slice(zx);
                }
            }
        })
    }

    /// One cached Φ application with θ resolved: `z`/`out` are the
    /// `[B, 1, d]` newest-position rows (decoder Y half only for the
    /// stacked EncDec state). Appends the layer's K/V column at
    /// `positions[b]` and fully overwrites `out`. Bidirectional layers
    /// (encoders, EncDec layers below n_enc) have no incremental form — a
    /// new position would rewrite every previous row — and report
    /// `CacheUnsupported`.
    fn apply_cached_into(
        &self,
        layer: usize,
        theta: &[f32],
        h: f32,
        cache: &mut KvCache,
        positions: &[usize],
        z: &[f32],
        out: &mut [f32],
    ) -> Result<(), CacheUnsupported> {
        let dm = RefDims { seq: 1, ..self.dims };
        let cap = self.dims.seq;
        match self.arch {
            Arch::Encoder => Err(CacheUnsupported),
            Arch::Decoder => {
                let lv = cache.layer_mut(layer - cache.layer0());
                self.with_scratch(|s| {
                    reference::enc_step_fwd_cached(z, theta, h, &dm, cap, positions, lv.k, lv.v,
                                                   out, s)
                });
                Ok(())
            }
            Arch::EncDec => {
                if layer < self.n_enc {
                    return Err(CacheUnsupported);
                }
                let lv = cache.layer_mut(layer - cache.layer0());
                self.with_scratch(|s| {
                    reference::dec_step_fwd_cached(z, theta, h, &dm, cap, positions, lv.k, lv.v,
                                                   cap, cap, lv.ck, lv.cv, out, s)
                });
                Ok(())
            }
        }
    }

    /// One adjoint application with θ resolved (`out` fully overwritten);
    /// `gtheta` receives the (discarded or consumed) parameter gradient.
    #[allow(clippy::too_many_arguments)]
    fn adjoint_into(
        &self,
        layer: usize,
        theta: &[f32],
        h: f32,
        z: &[f32],
        lam: &[f32],
        out: &mut [f32],
        gtheta: &mut [f32],
        s: &mut Scratch,
    ) {
        match self.arch {
            Arch::Encoder => {
                reference::enc_step_bwd_into(z, theta, h, &self.dims, false, lam, out, gtheta, s)
            }
            Arch::Decoder => {
                reference::enc_step_bwd_into(z, theta, h, &self.dims, true, lam, out, gtheta, s)
            }
            Arch::EncDec => {
                let half = z.len() / 2;
                let (zx, zy) = z.split_at(half);
                let (lx, ly) = lam.split_at(half);
                let (ox, oy) = out.split_at_mut(half);
                if layer < self.n_enc {
                    // X evolves: λx back through enc step; λy passes through
                    reference::enc_step_bwd_into(
                        zx, theta, h, &self.dims, false, lx, ox, gtheta, s,
                    );
                    oy.copy_from_slice(ly);
                } else {
                    // Y evolves: λy back through dec step; λx += ∂dec/∂X_enc
                    // (dec_step_bwd_into fully overwrites dxe)
                    let mut dxe = s.take_any(half);
                    reference::dec_step_bwd_into(
                        zy, zx, theta, h, &self.dims, self.dims.seq, ly, oy, &mut dxe, gtheta, s,
                    );
                    for ((o, &l), &d) in ox.iter_mut().zip(lx).zip(dxe.iter()) {
                        *o = l + d;
                    }
                    s.give(dxe);
                }
            }
        }
    }
}

impl Propagator for RustPropagator {
    fn n_steps(&self) -> usize {
        self.n_steps
    }

    fn state_shape(&self) -> Vec<usize> {
        let base = vec![self.dims.batch, self.dims.seq, self.dims.d_model];
        match self.arch {
            Arch::EncDec => {
                let mut s = vec![2];
                s.extend(base);
                s
            }
            _ => base,
        }
    }

    fn fine_h(&self, layer: usize) -> f32 {
        self.hs[layer]
    }

    fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor {
        let mut out = Tensor::zeros(z.shape());
        self.step_into(layer, h_scale, z, &mut out);
        out
    }

    /// Zero-allocation step at steady state: state slices in, state slices
    /// out, pooled scratch for every temporary.
    fn step_into(&self, layer: usize, h_scale: f32, z: &Tensor, out: &mut Tensor) {
        self.counters.count_fwd();
        let h = self.hs[layer] * h_scale;
        let params = self.params.read().unwrap();
        self.apply_into(layer, &params[layer], h, z.data(), out.data_mut());
        // deterministic chaos hook (one relaxed atomic load when disarmed,
        // rust/src/fault): hits count Φ forward kernel evaluations, so
        // `kernel.phi_nan@step=N` poisons the N-th evaluation's output —
        // the session's non-finite guard must catch it before Adam does
        if crate::faultpoint!("kernel.phi_nan") {
            out.data_mut()[0] = f32::NAN;
        }
    }

    /// Batched steps under a single read-lock acquisition (the v2
    /// dispatch-amortization entry point).
    fn step_range(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        h_scale: f32,
        z: &Tensor,
    ) -> Vec<Tensor> {
        let params = self.params.read().unwrap();
        let mut out: Vec<Tensor> = Vec::with_capacity(layer_hi.saturating_sub(layer_lo));
        for layer in layer_lo..layer_hi {
            self.counters.count_fwd();
            let h = self.hs[layer] * h_scale;
            let next = {
                let prev = out.last().unwrap_or(z);
                let mut t = Tensor::zeros(z.shape());
                self.apply_into(layer, &params[layer], h, prev.data(), t.data_mut());
                t
            };
            out.push(next);
        }
        out
    }

    /// Rolling full forward under a single read-lock acquisition: two
    /// ping-pong state buffers, no per-step allocation.
    fn step_to(&self, layer_lo: usize, layer_hi: usize, h_scale: f32, z: &Tensor) -> Tensor {
        let mut cur = z.clone();
        let mut next = Tensor::zeros(z.shape());
        self.step_to_into(layer_lo, layer_hi, h_scale, &mut cur, &mut next);
        cur
    }

    /// Caller-owned ping-pong buffers, still one read-lock acquisition for
    /// the whole sweep: the fully zero-allocation evaluation forward.
    fn step_to_into(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        h_scale: f32,
        cur: &mut Tensor,
        scratch: &mut Tensor,
    ) {
        let params = self.params.read().unwrap();
        for layer in layer_lo..layer_hi {
            self.counters.count_fwd();
            let h = self.hs[layer] * h_scale;
            self.apply_into(layer, &params[layer], h, cur.data(), scratch.data_mut());
            std::mem::swap(cur, scratch);
        }
    }

    /// In-place batched sweep under a single read-lock acquisition (the
    /// zero-allocation counterpart of `step_range`; buffer-layer sweeps).
    fn step_seq_into(&self, layer_lo: usize, h_scale: f32, states: &mut [Tensor]) {
        let params = self.params.read().unwrap();
        for i in 1..states.len() {
            self.counters.count_fwd();
            let layer = layer_lo + i - 1;
            let h = self.hs[layer] * h_scale;
            let (head, tail) = states.split_at_mut(i);
            self.apply_into(layer, &params[layer], h, head[i - 1].data(), tail[0].data_mut());
            // same chaos hook as `step_into`: hits share the Φ-evaluation
            // counting, whichever sweep shape the evaluation runs in
            if crate::faultpoint!("kernel.phi_nan") {
                tail[0].data_mut()[0] = f32::NAN;
            }
        }
    }

    fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam_next: &Tensor) -> Tensor {
        let mut out = Tensor::zeros(lam_next.shape());
        self.adjoint_step_into(layer, h_scale, z, lam_next, &mut out);
        out
    }

    fn adjoint_step_into(
        &self,
        layer: usize,
        h_scale: f32,
        z: &Tensor,
        lam_next: &Tensor,
        out: &mut Tensor,
    ) {
        self.counters.count_vjp();
        let h = self.hs[layer] * h_scale;
        let params = self.params.read().unwrap();
        let theta = &params[layer];
        self.with_scratch(|s| {
            // the adjoint discards θ-gradients; accumulate them into a
            // pooled zeroed buffer instead of allocating one per call
            let mut gtheta = s.take(theta.len());
            let (zd, ld) = (z.data(), lam_next.data());
            self.adjoint_into(layer, theta, h, zd, ld, out.data_mut(), &mut gtheta, s);
            s.give(gtheta);
        });
    }

    fn accumulate_grad(&self, layer: usize, z: &Tensor, lam_next: &Tensor, grad: &mut [f32]) {
        self.counters.count_vjp();
        let h = self.hs[layer];
        let params = self.params.read().unwrap();
        let theta = &params[layer];
        assert_eq!(theta.len(), grad.len(), "grad length mismatch at layer {}", layer);
        self.with_scratch(|s| {
            let lam_len = match self.arch {
                Arch::EncDec => z.len() / 2,
                _ => z.len(),
            };
            // the bwd entry points fully overwrite their λ outputs
            let mut dz = s.take_any(lam_len);
            match self.arch {
                Arch::Encoder => reference::enc_step_bwd_into(
                    z.data(), theta, h, &self.dims, false, lam_next.data(), &mut dz, grad, s,
                ),
                Arch::Decoder => reference::enc_step_bwd_into(
                    z.data(), theta, h, &self.dims, true, lam_next.data(), &mut dz, grad, s,
                ),
                Arch::EncDec => {
                    let half = z.len() / 2;
                    let (zx, zy) = z.data().split_at(half);
                    let (lx, ly) = lam_next.data().split_at(half);
                    if layer < self.n_enc {
                        reference::enc_step_bwd_into(
                            zx, theta, h, &self.dims, false, lx, &mut dz, grad, s,
                        );
                    } else {
                        let mut dxe = s.take_any(half);
                        reference::dec_step_bwd_into(
                            zy, zx, theta, h, &self.dims, self.dims.seq, ly, &mut dz, &mut dxe,
                            grad, s,
                        );
                        s.give(dxe);
                    }
                }
            }
            s.give(dz);
        });
    }

    fn theta_len(&self, layer: usize) -> usize {
        self.theta_lens[layer]
    }

    /// Decode cache sized for this model: one self-attention store per
    /// causal layer (all layers for `Decoder`, the dec stack for
    /// `EncDec`, which also carries the φ3 cross store for the frozen
    /// encoder output). Encoders are bidirectional → `None`.
    fn make_cache(&self) -> Option<KvCache> {
        let hd = self.dims.d_model / self.dims.n_heads;
        let (b, nh, seq) = (self.dims.batch, self.dims.n_heads, self.dims.seq);
        match self.arch {
            Arch::Encoder => None,
            Arch::Decoder => Some(KvCache::new(self.n_steps, 0, b, nh, hd, seq, 0)),
            Arch::EncDec => {
                Some(KvCache::new(self.n_steps - self.n_enc, self.n_enc, b, nh, hd, seq, seq))
            }
        }
    }

    fn step_cached(
        &self,
        layer: usize,
        cache: &mut KvCache,
        positions: &[usize],
        cur: &Tensor,
        out: &mut Tensor,
    ) -> Result<(), CacheUnsupported> {
        self.counters.count_cached();
        let params = self.params.read().unwrap();
        self.apply_cached_into(layer, &params[layer], self.hs[layer], cache, positions,
                               cur.data(), out.data_mut())
    }

    /// Cached sweep under a single read-lock acquisition — the per-token
    /// decode hot path: one O(1) Φ application per layer, zero heap
    /// allocations with a warm scratch pool.
    fn step_to_cached(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        cache: &mut KvCache,
        positions: &[usize],
        cur: &mut Tensor,
        scratch: &mut Tensor,
    ) -> Result<(), CacheUnsupported> {
        let params = self.params.read().unwrap();
        for layer in layer_lo..layer_hi {
            self.counters.count_cached();
            self.apply_cached_into(layer, &params[layer], self.hs[layer], cache, positions,
                                   cur.data(), scratch.data_mut())?;
            std::mem::swap(cur, scratch);
        }
        Ok(())
    }

    /// Prefill from the full-board layer-input state: projects the K/V
    /// columns `cache.len(b)..=positions[b]` per row out of `z`, bitwise
    /// what the cached steps would have appended walking those positions.
    /// For `EncDec`, encoder layers are a no-op and the first fill pass
    /// after a reset also primes each dec layer's φ3 cross store from the
    /// (frozen) X half; the caller flips `set_cross_primed(true)` once
    /// all layers are filled.
    fn fill_cached(
        &self,
        layer: usize,
        cache: &mut KvCache,
        z: &Tensor,
        positions: &[usize],
    ) -> Result<(), CacheUnsupported> {
        let (b, seq, d, nh) = (self.dims.batch, self.dims.seq, self.dims.d_model,
                               self.dims.n_heads);
        let params = self.params.read().unwrap();
        let theta = &params[layer];
        match self.arch {
            Arch::Encoder => Err(CacheUnsupported),
            Arch::Decoder => {
                let p = reference::EncParams::view(theta, d, self.dims.d_ff);
                let lv = cache.layer_mut(layer);
                self.with_scratch(|s| {
                    reference::fill_self_kv(z.data(), p.ln1_g, p.ln1_b, p.wk, p.wv, b, seq, d,
                                            nh, seq, lv.lens, positions, lv.k, lv.v, s)
                });
                Ok(())
            }
            Arch::EncDec => {
                if layer < self.n_enc {
                    return Ok(()); // encoder layers hold no decode-time columns
                }
                let p = reference::DecParams::view(theta, d, self.dims.d_ff);
                let (zx, zy) = z.data().split_at(z.len() / 2);
                let prime = !cache.cross_primed();
                let lv = cache.layer_mut(layer - self.n_enc);
                self.with_scratch(|s| {
                    reference::fill_self_kv(zy, p.enc.ln1_g, p.enc.ln1_b, p.enc.wk, p.enc.wv, b,
                                            seq, d, nh, seq, lv.lens, positions, lv.k, lv.v, s);
                    if prime {
                        reference::fill_cross_kv(zx, p.ck, p.cv, b, seq, d, nh, seq, lv.ck,
                                                 lv.cv, s);
                    }
                });
                Ok(())
            }
        }
    }

    fn counters(&self) -> &StepCounters {
        &self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_model(arch: Arch) -> ModelConfig {
        ModelConfig {
            arch,
            vocab: 8,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq: 4,
            batch: 1,
            n_classes: 2,
            n_enc_layers: if arch == Arch::EncDec { 2 } else { 4 },
            n_dec_layers: if arch == Arch::EncDec { 2 } else { 0 },
            buffer_open: 0,
            buffer_close: 0,
        }
    }

    pub fn make_params(model: &ModelConfig, rng: &mut Rng, std: f32) -> SharedParams {
        let mut v = Vec::new();
        for l in 0..model.total_layers() {
            let len = if model.arch == Arch::EncDec && l >= model.n_enc_layers {
                model.p_dec()
            } else {
                model.p_enc()
            };
            v.push(rng.normal_vec(len, std));
        }
        shared_params(v)
    }

    #[test]
    fn encoder_step_shape_preserved() {
        let model = tiny_model(Arch::Encoder);
        let mut rng = Rng::new(0);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let z2 = prop.step(0, 1.0, &z);
        assert_eq!(z2.shape(), z.shape());
    }

    #[test]
    fn encdec_encoder_phase_keeps_y_fixed() {
        let model = tiny_model(Arch::EncDec);
        let mut rng = Rng::new(1);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let z2 = prop.step(0, 1.0, &z); // encoder phase
        let half = z.len() / 2;
        assert_eq!(&z2.data()[half..], &z.data()[half..], "Y must not move");
        assert_ne!(&z2.data()[..half], &z.data()[..half], "X must move");
        let z3 = prop.step(2, 1.0, &z); // decoder phase (n_enc = 2)
        assert_eq!(&z3.data()[..half], &z.data()[..half], "X must not move");
        assert_ne!(&z3.data()[half..], &z.data()[half..], "Y must move");
    }

    #[test]
    fn prop_step_into_bitwise_matches_step_all_arches() {
        // The *_into acceptance property: for every Arch variant and layer
        // phase, the buffer-reusing entry points must reproduce the
        // allocating ones bit for bit, with `out` pre-filled with garbage
        // (pins the full-overwrite contract) and the scratch pool warm.
        for arch in [Arch::Encoder, Arch::Decoder, Arch::EncDec] {
            let model = tiny_model(arch);
            let mut rng = Rng::new(7);
            let params = make_params(&model, &mut rng, 0.15);
            let prop = RustPropagator::new(&model, 0.5, params);
            for layer in 0..model.total_layers() {
                for h_scale in [1.0f32, 2.0] {
                    let z = Tensor::randn(&mut rng, &prop.state_shape(), 0.8);
                    let lam = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);

                    let want = prop.step(layer, h_scale, &z);
                    let mut out = Tensor::randn(&mut rng, &prop.state_shape(), 9.0);
                    prop.step_into(layer, h_scale, &z, &mut out);
                    assert_eq!(out.data(), want.data(), "{:?} fwd layer {}", arch, layer);

                    let want = prop.adjoint_step(layer, h_scale, &z, &lam);
                    let mut out = Tensor::randn(&mut rng, &prop.state_shape(), 9.0);
                    prop.adjoint_step_into(layer, h_scale, &z, &lam, &mut out);
                    assert_eq!(out.data(), want.data(), "{:?} adj layer {}", arch, layer);
                }
            }
        }
    }

    #[test]
    fn step_range_matches_repeated_steps_bitwise() {
        let model = tiny_model(Arch::Encoder);
        let mut rng = Rng::new(5);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let batched = prop.step_range(0, 4, 1.0, &z);
        assert_eq!(batched.len(), 4);
        let mut cur = z.clone();
        for (l, b) in batched.iter().enumerate() {
            cur = prop.step(l, 1.0, &cur);
            assert_eq!(cur.data(), b.data(), "layer {}", l);
        }
        // the rolling variant lands on the same final state
        let rolled = prop.step_to(0, 4, 1.0, &z);
        assert_eq!(rolled.data(), batched.last().unwrap().data());
    }

    #[test]
    fn theta_len_is_cached_per_layer() {
        let model = tiny_model(Arch::EncDec);
        let mut rng = Rng::new(9);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params.clone());
        assert_eq!(prop.theta_len(0), model.p_enc());
        assert_eq!(prop.theta_len(1), model.p_enc());
        assert_eq!(prop.theta_len(2), model.p_dec());
        assert_eq!(prop.theta_len(3), model.p_dec());
        // cache agrees with the live store
        let live = params.read().unwrap();
        for l in 0..4 {
            assert_eq!(prop.theta_len(l), live[l].len());
        }
    }

    #[test]
    fn propagator_is_shareable_across_threads() {
        // the v2 contract: &RustPropagator can be used from worker threads
        let model = tiny_model(Arch::Encoder);
        let mut rng = Rng::new(6);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let want = prop.step(0, 1.0, &z);
        let outs: Vec<Tensor> = std::thread::scope(|s| {
            (0..4)
                .map(|_| s.spawn(|| prop.step(0, 1.0, &z)))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for o in outs {
            assert_eq!(o.data(), want.data());
        }
    }

    #[test]
    fn cached_sweep_matches_full_forward_rows_bitwise() {
        // The tentpole acceptance property at the propagator level: walk
        // the board left to right with step_to_cached (one [B,1,d] row in,
        // one O(1) sweep over all layers per position) and pin every
        // produced row bitwise against the rows of a full-board
        // step_seq_into over the same input. The cache columns consumed at
        // position p were appended during positions < p's sweeps, so this
        // is the real decode-loop induction, not a single-step check.
        let model = tiny_model(Arch::Decoder);
        let (b, s, d) = (model.batch, model.seq, model.d_model);
        let mut rng = Rng::new(11);
        let params = make_params(&model, &mut rng, 0.12);
        let prop = RustPropagator::new(&model, 0.5, params);
        let n = model.total_layers();

        let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 0.8);
        let mut states: Vec<Tensor> =
            (0..=n).map(|_| Tensor::zeros(&prop.state_shape())).collect();
        states[0] = z0.clone();
        prop.step_seq_into(0, 1.0, &mut states);

        let mut cache = prop.make_cache().expect("decoder supports incremental decode");
        let mut cur = Tensor::zeros(&[b, 1, d]);
        let mut pp = Tensor::zeros(&[b, 1, d]);
        for pos in 0..s {
            for r in 0..b {
                let src = (r * s + pos) * d;
                cur.data_mut()[r * d..(r + 1) * d].copy_from_slice(&z0.data()[src..src + d]);
            }
            prop.step_to_cached(0, n, &mut cache, &[pos], &mut cur, &mut pp).unwrap();
            cache.commit(&[pos]);
            for r in 0..b {
                let want = (r * s + pos) * d;
                assert_eq!(&cur.data()[r * d..(r + 1) * d],
                           &states[n].data()[want..want + d],
                           "row {} position {}", r, pos);
            }
        }
        assert_eq!(prop.counters().cached(), (s * n) as u64);
    }

    #[test]
    fn cached_dec_sweep_matches_full_forward_y_rows_bitwise() {
        // EncDec variant: prefill at position 0 (fill_cached over every
        // layer from the full-forward intermediates + commit), then decode
        // positions 1.. with cached sweeps over the dec stack only. The Y
        // rows must match the full forward bitwise; the X half never moves
        // through dec layers, so the cross store primed at prefill covers
        // every step.
        let model = tiny_model(Arch::EncDec);
        let (s, d) = (model.seq, model.d_model);
        let mut rng = Rng::new(12);
        let params = make_params(&model, &mut rng, 0.12);
        let prop = RustPropagator::new(&model, 0.5, params);
        let n = model.total_layers();

        let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 0.8);
        let mut states: Vec<Tensor> =
            (0..=n).map(|_| Tensor::zeros(&prop.state_shape())).collect();
        states[0] = z0.clone();
        prop.step_seq_into(0, 1.0, &mut states);

        let mut cache = prop.make_cache().expect("encdec supports incremental decode");
        assert_eq!(cache.layer0(), model.n_enc_layers);
        for l in 0..n {
            prop.fill_cached(l, &mut cache, &states[l], &[0]).unwrap();
        }
        cache.set_cross_primed(true);
        cache.commit(&[0]);

        let half = z0.len() / 2;
        let mut cur = Tensor::zeros(&[1, 1, d]);
        let mut pp = Tensor::zeros(&[1, 1, d]);
        for pos in 1..s {
            let src = half + pos * d;
            cur.data_mut().copy_from_slice(&z0.data()[src..src + d]);
            prop.step_to_cached(model.n_enc_layers, n, &mut cache, &[pos], &mut cur, &mut pp)
                .unwrap();
            cache.commit(&[pos]);
            assert_eq!(cur.data(), &states[n].data()[src..src + d], "Y position {}", pos);
        }
    }

    #[test]
    fn encoder_arch_has_no_decode_cache() {
        let model = tiny_model(Arch::Encoder);
        let mut rng = Rng::new(13);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        assert!(prop.make_cache().is_none(), "bidirectional attention cannot decode in place");
    }

    #[test]
    fn adjoint_consistent_with_fd_dot_product() {
        // <Φ(z+εu) - Φ(z), v> ≈ ε <u, Φ'ᵀ v>
        let model = tiny_model(Arch::EncDec);
        let mut rng = Rng::new(2);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        for layer in [0usize, 2] {
            let z = Tensor::randn(&mut rng, &prop.state_shape(), 0.7);
            let u = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
            let v = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
            let eps = 1e-3;
            let mut zp = z.clone();
            zp.axpy(eps, &u);
            let mut zm = z.clone();
            zm.axpy(-eps, &u);
            let fd = (prop.step(layer, 1.0, &zp).dot(&v) - prop.step(layer, 1.0, &zm).dot(&v))
                / (2.0 * eps);
            let adj = prop.adjoint_step(layer, 1.0, &z, &v);
            let want = u.dot(&adj);
            assert!(
                (fd - want).abs() < 2e-2 * (1.0 + want.abs()),
                "layer {}: fd={} adj={}",
                layer,
                fd,
                want
            );
        }
    }
}
