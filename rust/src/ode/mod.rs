//! The neural-ODE abstraction MGRIT solves over: a [`Propagator`] is the
//! discrete forward operator Φ (one Euler layer-step, paper eq. 3) together
//! with its adjoint (VJP).
//!
//! Three implementations:
//! * [`LinearOde`] — dz/dt = A z, the analytically-tractable test problem
//!   the MGRIT convergence tests are pinned on;
//! * [`RustPropagator`] — the pure-Rust reference transformer (artifact-free
//!   testing and analysis tooling);
//! * [`XlaPropagator`] — the production path: AOT artifacts through PJRT.
//!
//! Encoder-decoder architectures use the paper's *stacked* state
//! Z = [X, Y] (eq. 3): Φ advances X during encoder time, Y during decoder
//! time, holding the other component fixed.

mod linear;
mod propagator;
mod rust_prop;
mod xla_prop;

pub use linear::LinearOde;
pub use propagator::{CacheUnsupported, Propagator, StepCounters};
pub use rust_prop::{layer_hs, shared_params, RustPropagator, SharedParams};
pub use xla_prop::XlaPropagator;
