//! dz/dt = A z — the linear test problem the MGRIT literature (Dobrev et
//! al. 2017) analyzes. Forward Euler: Φ(z) = (I + hA) z. Used to pin
//! MGRIT's exactness, two-level convergence, and adjoint correctness.

use super::propagator::{Propagator, StepCounters};
use crate::tensor::{matmul, matmul_at, Tensor};

/// Linear autonomous ODE with a dense system matrix A [d,d].
pub struct LinearOde {
    a: Tensor,
    n_steps: usize,
    h: f32,
    dim: usize,
    counters: StepCounters,
}

impl LinearOde {
    pub fn new(a: Tensor, n_steps: usize, h: f32) -> LinearOde {
        let dim = a.shape()[0];
        assert_eq!(a.shape(), &[dim, dim]);
        LinearOde { a, n_steps, h, dim, counters: StepCounters::default() }
    }

    /// Stable diagonal-ish random system: A = -I + 0.3·N(0,1)/√d.
    pub fn random_stable(rng: &mut crate::util::rng::Rng, dim: usize, n_steps: usize, h: f32) -> LinearOde {
        let mut a = Tensor::randn(rng, &[dim, dim], 0.3 / (dim as f32).sqrt());
        for i in 0..dim {
            a.data_mut()[i * dim + i] -= 1.0;
        }
        LinearOde::new(a, n_steps, h)
    }

    /// Exact serial Euler trajectory (ground truth for tests).
    pub fn serial_trajectory(&self, z0: &Tensor) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(self.n_steps + 1);
        out.push(z0.clone());
        for n in 0..self.n_steps {
            let prev = out[n].clone();
            out.push(self.step(n, 1.0, &prev));
        }
        out
    }
}

impl Propagator for LinearOde {
    fn n_steps(&self) -> usize {
        self.n_steps
    }

    fn state_shape(&self) -> Vec<usize> {
        vec![self.dim, 1]
    }

    fn fine_h(&self, _layer: usize) -> f32 {
        self.h
    }

    fn step(&self, _layer: usize, h_scale: f32, z: &Tensor) -> Tensor {
        self.counters.count_fwd();
        let h = self.h * h_scale;
        let az = matmul(&self.a, z);
        let mut out = z.clone();
        out.axpy(h, &az);
        out
    }

    fn adjoint_step(&self, _layer: usize, h_scale: f32, _z: &Tensor, lam_next: &Tensor) -> Tensor {
        self.counters.count_vjp();
        let h = self.h * h_scale;
        // (I + hA)ᵀ λ = λ + h Aᵀ λ
        let atl = matmul_at(&self.a, lam_next);
        let mut out = lam_next.clone();
        out.axpy(h, &atl);
        out
    }

    fn accumulate_grad(&self, _layer: usize, _z: &Tensor, _lam: &Tensor, _grad: &mut [f32]) {
        // A is fixed in the test problem — no trainable parameters.
    }

    fn theta_len(&self, _layer: usize) -> usize {
        0
    }

    fn counters(&self) -> &StepCounters {
        &self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn serial_trajectory_decays_for_stable_system() {
        let mut rng = Rng::new(0);
        let ode = LinearOde::random_stable(&mut rng, 8, 64, 0.1);
        let z0 = Tensor::randn(&mut rng, &[8, 1], 1.0);
        let traj = ode.serial_trajectory(&z0);
        assert_eq!(traj.len(), 65);
        assert!(traj[64].norm() < traj[0].norm());
    }

    #[test]
    fn adjoint_is_transpose() {
        // <Φ u, v> == <u, Φᵀ v>
        let mut rng = Rng::new(1);
        let ode = LinearOde::random_stable(&mut rng, 6, 4, 0.2);
        let u = Tensor::randn(&mut rng, &[6, 1], 1.0);
        let v = Tensor::randn(&mut rng, &[6, 1], 1.0);
        let fu = ode.step(0, 2.0, &u);
        let atv = ode.adjoint_step(0, 2.0, &u, &v);
        assert!((fu.dot(&v) - u.dot(&atv)).abs() < 1e-4);
    }

    #[test]
    fn default_into_entry_points_match_allocating_ones() {
        // LinearOde relies on the trait's default step_into/adjoint_step_into
        let mut rng = Rng::new(3);
        let ode = LinearOde::random_stable(&mut rng, 5, 4, 0.2);
        let z = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let lam = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let mut out = Tensor::randn(&mut rng, &[5, 1], 1.0); // garbage: overwritten
        ode.step_into(1, 2.0, &z, &mut out);
        assert_eq!(out.data(), ode.step(1, 2.0, &z).data());
        ode.adjoint_step_into(1, 2.0, &z, &lam, &mut out);
        assert_eq!(out.data(), ode.adjoint_step(1, 2.0, &z, &lam).data());
    }

    #[test]
    fn counters_track_evals() {
        let mut rng = Rng::new(2);
        let ode = LinearOde::random_stable(&mut rng, 4, 8, 0.1);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        ode.serial_trajectory(&z0);
        assert_eq!(ode.counters().fwd(), 8);
    }
}
