//! Production propagator: Φ and its VJP as AOT-compiled XLA programs
//! executed through PJRT. One compiled executable per entry point, reused
//! across all layers and MGRIT levels (h is a runtime scalar).
//!
//! v2: the engine is shared as `Arc<XlaEngine>` and the propagator is
//! `Send + Sync`, so the threaded MGRIT backend can execute Φ from worker
//! threads (PJRT executables are thread-safe; see `runtime::engine`).

use std::sync::Arc;

use super::propagator::{Propagator, StepCounters};
use super::rust_prop::SharedParams;
use crate::config::{Arch, ModelConfig};
use crate::runtime::{Value, XlaEngine};
use crate::tensor::Tensor;

/// XLA-backed propagator over the MGRIT domain.
pub struct XlaPropagator {
    engine: Arc<XlaEngine>,
    arch: Arch,
    n_enc: usize,
    n_steps: usize,
    hs: Vec<f32>,
    p_enc: usize,
    p_dec: usize,
    inner_shape: Vec<usize>,
    params: SharedParams,
    counters: StepCounters,
}

impl XlaPropagator {
    pub fn new(
        engine: Arc<XlaEngine>,
        model: &ModelConfig,
        h: f32,
        params: SharedParams,
    ) -> anyhow::Result<XlaPropagator> {
        let n = params.read().unwrap().len();
        Self::with_hs(engine, model, vec![h; n], params)
    }

    /// Buffer-aware constructor (Δt per layer from `ode::layer_hs`).
    pub fn for_model(
        engine: Arc<XlaEngine>,
        model: &ModelConfig,
        params: SharedParams,
    ) -> anyhow::Result<XlaPropagator> {
        let n = params.read().unwrap().len();
        Self::with_hs(engine, model, super::rust_prop::layer_hs(model, n), params)
    }

    pub fn with_hs(
        engine: Arc<XlaEngine>,
        model: &ModelConfig,
        hs: Vec<f32>,
        params: SharedParams,
    ) -> anyhow::Result<XlaPropagator> {
        engine.manifest().validate_model(model)?;
        let n_steps = params.read().unwrap().len();
        assert_eq!(hs.len(), n_steps);
        Ok(XlaPropagator {
            engine,
            arch: model.arch,
            n_enc: if model.arch == Arch::EncDec { model.n_enc_layers } else { 0 },
            n_steps,
            hs,
            p_enc: model.p_enc(),
            p_dec: model.p_dec(),
            inner_shape: vec![model.batch, model.seq, model.d_model],
            params,
            counters: StepCounters::default(),
        })
    }

    fn theta_value(&self, layer: usize) -> Value {
        let params = self.params.read().unwrap();
        let th = &params[layer];
        Value::F32(Tensor::from_vec(th.clone(), &[th.len()]))
    }

    fn split(&self, z: &Tensor) -> (Tensor, Tensor) {
        let half = z.len() / 2;
        (
            Tensor::from_vec(z.data()[..half].to_vec(), &self.inner_shape),
            Tensor::from_vec(z.data()[half..].to_vec(), &self.inner_shape),
        )
    }

    fn join(&self, x: &Tensor, y: &Tensor) -> Tensor {
        let mut data = Vec::with_capacity(x.len() * 2);
        data.extend_from_slice(x.data());
        data.extend_from_slice(y.data());
        Tensor::from_vec(data, &self.state_shape())
    }

    fn enc_entry(&self) -> &'static str {
        match self.arch {
            Arch::Decoder => "causal_step",
            _ => "enc_step",
        }
    }

    /// Shared body of `step_range`/`step_to`: consecutive Φ applications
    /// over `[layer_lo, layer_hi)` with the executable resolved once.
    /// `keep_intermediates` keeps every state (for relaxation/buffer
    /// sweeps); otherwise only the final state survives (O(1) memory,
    /// for evaluation forwards).
    fn drive_range(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        h_scale: f32,
        z: &Tensor,
        keep_intermediates: bool,
    ) -> Vec<Tensor> {
        let n = layer_hi.saturating_sub(layer_lo);
        let cap = if keep_intermediates { n } else { n.min(1) };
        let mut out: Vec<Tensor> = Vec::with_capacity(cap);
        match self.arch {
            Arch::Encoder | Arch::Decoder => {
                let entry = self.enc_entry();
                let exe = self.engine.executable(entry).expect("Φ entry point missing");
                self.engine.note_calls(entry, n as u64);
                for layer in layer_lo..layer_hi {
                    self.counters.count_fwd();
                    let h = self.hs[layer] * h_scale;
                    let prev = out.last().unwrap_or(z).clone();
                    let args = [Value::F32(prev), self.theta_value(layer), Value::scalar(h)];
                    let next = exe.call(&args).expect("Φ step failed").into_iter().next().unwrap();
                    if !keep_intermediates {
                        out.clear();
                    }
                    out.push(next);
                }
            }
            // the stacked state alternates enc/dec entry points — fall back
            // to per-step dispatch
            Arch::EncDec => {
                for layer in layer_lo..layer_hi {
                    let next = self.step(layer, h_scale, out.last().unwrap_or(z));
                    if !keep_intermediates {
                        out.clear();
                    }
                    out.push(next);
                }
            }
        }
        out
    }
}

impl Propagator for XlaPropagator {
    fn n_steps(&self) -> usize {
        self.n_steps
    }

    fn state_shape(&self) -> Vec<usize> {
        match self.arch {
            Arch::EncDec => {
                let mut s = vec![2];
                s.extend(self.inner_shape.clone());
                s
            }
            _ => self.inner_shape.clone(),
        }
    }

    fn fine_h(&self, layer: usize) -> f32 {
        self.hs[layer]
    }

    fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor {
        self.counters.count_fwd();
        let h = self.hs[layer] * h_scale;
        match self.arch {
            Arch::Encoder | Arch::Decoder => {
                let out = self
                    .engine
                    .call(
                        self.enc_entry(),
                        &[Value::F32(z.clone()), self.theta_value(layer), Value::scalar(h)],
                    )
                    .expect("Φ step failed");
                out.into_iter().next().unwrap()
            }
            Arch::EncDec => {
                let (x, y) = self.split(z);
                if layer < self.n_enc {
                    let out = self
                        .engine
                        .call(
                            "enc_step",
                            &[Value::F32(x), self.theta_value(layer), Value::scalar(h)],
                        )
                        .expect("enc Φ failed");
                    self.join(&out[0], &y)
                } else {
                    let out = self
                        .engine
                        .call(
                            "dec_step",
                            &[
                                Value::F32(y),
                                Value::F32(x.clone()),
                                self.theta_value(layer),
                                Value::scalar(h),
                            ],
                        )
                        .expect("dec Φ failed");
                    self.join(&x, &out[0])
                }
            }
        }
    }

    /// Batched steps with the executable resolved once (the v2
    /// dispatch-amortization entry point: one cache lookup, one call-counter
    /// bump, per chunk instead of per layer).
    fn step_range(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        h_scale: f32,
        z: &Tensor,
    ) -> Vec<Tensor> {
        self.drive_range(layer_lo, layer_hi, h_scale, z, true)
    }

    /// Rolling full forward with the executable resolved once.
    fn step_to(&self, layer_lo: usize, layer_hi: usize, h_scale: f32, z: &Tensor) -> Tensor {
        self.drive_range(layer_lo, layer_hi, h_scale, z, false)
            .pop()
            .unwrap_or_else(|| z.clone())
    }

    /// Buffer-reusing rolling forward. XLA marshals fresh output buffers
    /// per call anyway, so this delegates to the amortized `step_to` (one
    /// executable lookup for the sweep) and copies the result into `cur`
    /// — the zero-allocation contract is the Rust propagator's.
    fn step_to_into(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        h_scale: f32,
        cur: &mut Tensor,
        _scratch: &mut Tensor,
    ) {
        let out = self.step_to(layer_lo, layer_hi, h_scale, cur);
        cur.copy_from(&out);
    }

    /// In-place batched sweep: one executable lookup for the whole chunk
    /// (via `step_range`), results copied into the caller's buffers.
    fn step_seq_into(&self, layer_lo: usize, h_scale: f32, states: &mut [Tensor]) {
        let n = states.len().saturating_sub(1);
        if n == 0 {
            return;
        }
        let out = self.step_range(layer_lo, layer_lo + n, h_scale, &states[0]);
        for (dst, src) in states[1..].iter_mut().zip(&out) {
            dst.copy_from(src);
        }
    }

    fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam_next: &Tensor) -> Tensor {
        self.counters.count_vjp();
        let h = self.hs[layer] * h_scale;
        match self.arch {
            Arch::Encoder | Arch::Decoder => {
                let entry = match self.arch {
                    Arch::Decoder => "causal_step_vjp",
                    _ => "enc_step_vjp",
                };
                let out = self
                    .engine
                    .call(
                        entry,
                        &[
                            Value::F32(z.clone()),
                            self.theta_value(layer),
                            Value::scalar(h),
                            Value::F32(lam_next.clone()),
                        ],
                    )
                    .expect("adjoint step failed");
                out.into_iter().next().unwrap()
            }
            Arch::EncDec => {
                let (x, y) = self.split(z);
                let (lx, ly) = self.split(lam_next);
                if layer < self.n_enc {
                    let out = self
                        .engine
                        .call(
                            "enc_step_vjp",
                            &[
                                Value::F32(x),
                                self.theta_value(layer),
                                Value::scalar(h),
                                Value::F32(lx),
                            ],
                        )
                        .expect("enc adjoint failed");
                    self.join(&out[0], &ly)
                } else {
                    let out = self
                        .engine
                        .call(
                            "dec_step_vjp",
                            &[
                                Value::F32(y),
                                Value::F32(x),
                                self.theta_value(layer),
                                Value::scalar(h),
                                Value::F32(ly),
                            ],
                        )
                        .expect("dec adjoint failed");
                    let mut lx2 = lx;
                    lx2.axpy(1.0, &out[1]); // λ_x += ∂dec/∂X_enc contribution
                    self.join(&lx2, &out[0])
                }
            }
        }
    }

    fn accumulate_grad(&self, layer: usize, z: &Tensor, lam_next: &Tensor, grad: &mut [f32]) {
        self.counters.count_vjp();
        let h = self.hs[layer];
        let g = match self.arch {
            Arch::Encoder | Arch::Decoder => {
                let entry = match self.arch {
                    Arch::Decoder => "causal_step_vjp",
                    _ => "enc_step_vjp",
                };
                let out = self
                    .engine
                    .call(
                        entry,
                        &[
                            Value::F32(z.clone()),
                            self.theta_value(layer),
                            Value::scalar(h),
                            Value::F32(lam_next.clone()),
                        ],
                    )
                    .expect("grad step failed");
                out.into_iter().nth(1).unwrap()
            }
            Arch::EncDec => {
                let (x, y) = self.split(z);
                let (lx, ly) = self.split(lam_next);
                if layer < self.n_enc {
                    let out = self
                        .engine
                        .call(
                            "enc_step_vjp",
                            &[
                                Value::F32(x),
                                self.theta_value(layer),
                                Value::scalar(h),
                                Value::F32(lx),
                            ],
                        )
                        .expect("enc grad failed");
                    out.into_iter().nth(1).unwrap()
                } else {
                    let out = self
                        .engine
                        .call(
                            "dec_step_vjp",
                            &[
                                Value::F32(y),
                                Value::F32(x),
                                self.theta_value(layer),
                                Value::scalar(h),
                                Value::F32(ly),
                            ],
                        )
                        .expect("dec grad failed");
                    out.into_iter().nth(2).unwrap()
                }
            }
        };
        assert_eq!(g.len(), grad.len());
        for (a, b) in grad.iter_mut().zip(g.data()) {
            *a += b;
        }
    }

    fn theta_len(&self, layer: usize) -> usize {
        if self.arch == Arch::EncDec && layer >= self.n_enc {
            self.p_dec
        } else {
            self.p_enc
        }
    }

    fn counters(&self) -> &StepCounters {
        &self.counters
    }
}
