//! Checkpoint hot-reload: watch a directory for newer `LTCP` files.
//!
//! The training side drops autosaves into a directory
//! (`layertime train --save-every N --keep K`, named so lexicographic
//! order equals chronological order — see
//! [`crate::checkpoint::autosave_path`]); a long-running `serve` process
//! polls that directory **between** decode steps and swaps to the newest
//! valid checkpoint via
//! [`crate::infer::InferSession::swap_checkpoint`]. Files that fail to
//! read — truncated mid-write, FNV-1a checksum mismatch, wrong version —
//! are remembered as bad and skipped on every later poll instead of
//! taking the service down; an older valid file wins over a newer corrupt
//! one.

use std::path::{Path, PathBuf};
use std::time::SystemTime;

use crate::checkpoint::Checkpoint;

/// Newest-first ordering key: modification time, then file name (the
/// autosave naming embeds the zero-padded step, so the name breaks ties
/// between files written within one timestamp granule).
type FileKey = (SystemTime, String);

/// Directory watcher for `*.ltcp` checkpoints (see module docs).
pub struct HotReload {
    dir: PathBuf,
    /// Key of the checkpoint currently being served (never re-offered).
    loaded: Option<FileKey>,
    /// Files that failed to read — skipped forever (a rewritten file gets
    /// a new mtime and therefore a new key).
    bad: Vec<FileKey>,
}

impl HotReload {
    pub fn new(dir: &str) -> HotReload {
        HotReload { dir: PathBuf::from(dir), loaded: None, bad: Vec::new() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Name of the currently loaded checkpoint file, if any.
    pub fn loaded_name(&self) -> Option<&str> {
        self.loaded.as_ref().map(|(_, n)| n.as_str())
    }

    /// How many files have been quarantined as unreadable.
    pub fn bad_files(&self) -> usize {
        self.bad.len()
    }

    /// Mark the most recently returned checkpoint as unusable after all
    /// (e.g. it read fine but its model config doesn't match the serving
    /// session): quarantine it and forget it was loaded, so the next poll
    /// falls back to the next-best file.
    pub fn reject_loaded(&mut self) {
        if let Some(key) = self.loaded.take() {
            crate::fault::record(
                "serve.reload",
                0,
                "reload_quarantined",
                format!("{}: checkpoint incompatible with serving session", key.1),
            );
            self.bad.push(key);
        }
    }

    /// Scan the directory and return the newest valid checkpoint that is
    /// strictly newer than the one already loaded (`None` when nothing
    /// newer and valid exists). Unreadable candidates are quarantined and
    /// the scan falls through to older files.
    pub fn poll(&mut self) -> Option<(PathBuf, Checkpoint)> {
        let entries = std::fs::read_dir(&self.dir).ok()?;
        let mut candidates: Vec<(FileKey, PathBuf)> = entries
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let path = e.path();
                let name = path.file_name()?.to_str()?.to_string();
                if !name.ends_with(".ltcp") {
                    return None;
                }
                let mtime = e.metadata().ok()?.modified().ok()?;
                Some(((mtime, name), path))
            })
            .collect();
        // newest first
        candidates.sort_by(|a, b| b.0.cmp(&a.0));
        for (key, path) in candidates {
            if let Some(loaded) = &self.loaded {
                if key <= *loaded {
                    // everything from here on is older than what we serve
                    return None;
                }
            }
            if self.bad.contains(&key) {
                continue;
            }
            match Checkpoint::read(&path.to_string_lossy()) {
                Ok(ck) => {
                    self.loaded = Some(key);
                    return Some((path, ck));
                }
                Err(e) => {
                    // truncated / checksum-failed / foreign file: skip it
                    // now and forever, keep looking at older candidates
                    crate::fault::record(
                        "serve.reload",
                        0,
                        "reload_quarantined",
                        format!("{}: {}", key.1, e),
                    );
                    self.bad.push(key);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("layertime_reload_{}_{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn empty_or_missing_dir_polls_none() {
        let d = tmp_dir("empty");
        let mut hr = HotReload::new(d.to_str().unwrap());
        assert!(hr.poll().is_none());
        let mut gone = HotReload::new("/nonexistent/layertime/watch/dir");
        assert!(gone.poll().is_none());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_files_are_quarantined_not_fatal() {
        let d = tmp_dir("corrupt");
        std::fs::write(d.join("model.step00000001.ltcp"), b"not a checkpoint").unwrap();
        let mut hr = HotReload::new(d.to_str().unwrap());
        assert!(hr.poll().is_none(), "the only file is corrupt");
        assert_eq!(hr.bad_files(), 1);
        // a second poll doesn't re-read the quarantined file
        assert!(hr.poll().is_none());
        assert_eq!(hr.bad_files(), 1);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn non_ltcp_files_are_ignored() {
        let d = tmp_dir("ignore");
        std::fs::write(d.join("notes.txt"), b"hello").unwrap();
        let mut hr = HotReload::new(d.to_str().unwrap());
        assert!(hr.poll().is_none());
        assert_eq!(hr.bad_files(), 0);
        let _ = std::fs::remove_dir_all(&d);
    }
}
