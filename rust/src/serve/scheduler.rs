//! Dynamic-batch scheduler over the session's fixed decode slots.
//!
//! [`ServeLoop`] owns an [`InferSession`] and a `[B, seq]` token board.
//! Each [`ServeLoop::step`] is one decode step for the whole batch:
//!
//! 1. (periodically) poll the [`super::HotReload`] watcher — weights only
//!    ever swap **between** steps, so every request's step-`p` token comes
//!    from exactly one checkpoint snapshot;
//! 2. sweep per-request deadlines — a slot whose
//!    [`GenerateRequest::deadline_ms`] budget expired retires immediately
//!    with [`super::RequestOutcome::Timeout`] and its tokens so far (the
//!    `serve.deadline` fault point forces this deterministically) — then
//!    admit queued requests into free slots: each newcomer's board row is
//!    rewritten (prompt + zeroed tail, exactly the solo layout) and named
//!    in `cold_rows` so the forward resets just that row's warm iterate;
//! 3. one batched forward — with incremental decode on (the session
//!    default) via [`InferSession::forward_board_cached`]: a **prefill**
//!    step (joiners present or the cache is stale) runs one exact
//!    full-board forward that also ingests the missing K/V columns, and a
//!    **steady** step is a single cached O(1)-per-layer Φ sweep; with it
//!    off, every step is a full [`InferSession::forward_board`] — then a
//!    per-row logit projection at each slot's own cursor
//!    ([`InferSession::logits_rows`]);
//! 4. per-slot token selection from the slot's own RNG stream
//!    (`Rng::new(request.seed)` — slot- and occupancy-independent), then
//!    retirement of slots that reached their budget (each retired row's
//!    cache columns are released for the next occupant).
//!
//! Because every forward/head kernel is batch-row independent (see
//! `super` docs), an active row's token sequence is bitwise identical to
//! running that request alone — pinned by `rust/tests/serve_parity.rs` —
//! and the steady-state step performs no allocations — pinned by
//! `rust/tests/alloc_audit.rs`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::coordinator::Task;
use crate::infer::{pick_token, DecodeOptions, InferSession};
use crate::util::rng::Rng;

use super::metrics::ServeMetrics;
use super::queue::RequestQueue;
use super::reload::HotReload;
use super::{CompletedRequest, GenerateRequest, RequestOutcome, ServeError};

/// What one scheduler step did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// No slot active (and nothing admitted): no forward ran.
    Idle,
    /// A forward ran; the payload is the batch occupancy (= tokens
    /// emitted this step).
    Decoded(usize),
}

/// One decode slot's bookkeeping (scalars only — installing a request
/// into a slot never allocates).
struct Slot {
    active: bool,
    id: u64,
    /// The slot's private sampling stream, `Rng::new(request.seed)`.
    rng: Rng,
    opts: DecodeOptions,
    /// Next board position to fill (logits are read at `cursor − 1`).
    cursor: usize,
    /// One past the last position this request may fill.
    end: usize,
    prompt_len: usize,
    submitted_at: Instant,
    /// Time-to-first-token, set when the first token lands.
    ttft: Option<f64>,
    /// Wall-clock budget in ms from submission; `0` = none.
    deadline_ms: u64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            active: false,
            id: 0,
            rng: Rng::new(0),
            opts: DecodeOptions::default(),
            cursor: 0,
            end: 0,
            prompt_len: 0,
            submitted_at: Instant::now(),
            ttft: None,
            deadline_ms: 0,
        }
    }
}

/// The continuous-batching serve loop (see module docs).
pub struct ServeLoop {
    session: InferSession,
    queue: Arc<RequestQueue>,
    slots: Vec<Slot>,
    /// `[B, seq]` token board; active rows hold prompt + generated-so-far,
    /// retired rows keep their stale tokens (row independence makes them
    /// inert).
    board: Vec<i32>,
    /// Per-row logit positions for [`InferSession::logits_rows`].
    positions: Vec<usize>,
    /// Rows whose occupant changed this step (warm-iterate reset set).
    cold_rows: Vec<usize>,
    /// Rows retired this step (their cache columns are released after the
    /// selection loop drops the logits borrow).
    retired: Vec<usize>,
    /// Shared top-k scratch (capacity grows to max k once, then reused).
    topk_idx: Vec<usize>,
    topk_val: Vec<f32>,
    completed: Vec<CompletedRequest>,
    pub metrics: ServeMetrics,
    reload: Option<HotReload>,
    reload_every: u64,
    steps: u64,
}

impl ServeLoop {
    /// Wrap a causal-LM session; `queue_capacity` is the backpressure
    /// high-water mark. The session's warm state is dropped so the loop
    /// starts from a clean, deterministic slate.
    pub fn new(mut session: InferSession, queue_capacity: usize) -> Result<ServeLoop> {
        ensure!(
            session.task() == Task::Lm,
            "serve drives the causal LM head; task {:?} cannot autoregress",
            session.task()
        );
        let (b, s) = (session.rc.model.batch, session.rc.model.seq);
        ensure!(s >= 2, "seq {} leaves no room to generate", s);
        session.reset_warm();
        let queue = Arc::new(RequestQueue::new(queue_capacity, s - 1));
        Ok(ServeLoop {
            queue,
            slots: (0..b).map(|_| Slot::empty()).collect(),
            board: vec![0; b * s],
            positions: vec![0; b],
            cold_rows: Vec::with_capacity(b),
            retired: Vec::with_capacity(b),
            topk_idx: Vec::new(),
            topk_val: Vec::new(),
            completed: Vec::new(),
            metrics: ServeMetrics::with_capacity(4096),
            reload: None,
            reload_every: 0,
            steps: 0,
            session,
        })
    }

    /// A handle for producers (feeder threads) to submit and close on.
    pub fn queue(&self) -> Arc<RequestQueue> {
        Arc::clone(&self.queue)
    }

    /// Convenience single-producer submit.
    pub fn submit(&self, req: GenerateRequest) -> Result<(), ServeError> {
        self.queue.submit(req)
    }

    /// Attach a checkpoint watcher, polled every `every` steps (and on
    /// [`ServeLoop::reload_now`]). Pass the [`HotReload`] whose `poll`
    /// already yielded the currently-served checkpoint so it isn't
    /// immediately re-offered.
    pub fn set_watch(&mut self, watch: HotReload, every: u64) {
        self.reload = Some(watch);
        self.reload_every = every.max(1);
    }

    /// Poll the watcher immediately (still a between-steps boundary).
    /// Returns whether a newer checkpoint was swapped in. A checkpoint
    /// that reads fine but doesn't match the serving model is quarantined
    /// like a corrupt file.
    pub fn reload_now(&mut self) -> bool {
        let hr = match self.reload.as_mut() {
            Some(h) => h,
            None => return false,
        };
        match hr.poll() {
            Some((_path, ck)) => match self.session.swap_checkpoint(&ck) {
                Ok(()) => {
                    self.metrics.reloads += 1;
                    true
                }
                Err(_) => {
                    hr.reject_loaded();
                    false
                }
            },
            None => false,
        }
    }

    pub fn session(&self) -> &InferSession {
        &self.session
    }

    /// Number of currently active slots.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.active).count()
    }

    /// Drain the requests completed since the last call.
    pub fn take_completed(&mut self) -> Vec<CompletedRequest> {
        std::mem::take(&mut self.completed)
    }

    /// Recover the session (e.g. to hand it back to other inference).
    pub fn into_session(self) -> InferSession {
        self.session
    }

    /// Install `req` into free slot `r`: rewrite the board row to
    /// prompt + zeroed tail (the exact solo-decode layout, so the row's
    /// cold first solve is bitwise the solo one) and reset the slot's
    /// cursor, budget, and RNG stream.
    fn install(&mut self, r: usize, req: GenerateRequest, submitted_at: Instant) {
        let s = self.session.rc.model.seq;
        let plen = req.prompt.len();
        debug_assert!(plen >= 1 && plen < s, "queue validation admitted prompt_len {}", plen);
        let row = &mut self.board[r * s..(r + 1) * s];
        row[..plen].copy_from_slice(&req.prompt);
        row[plen..].fill(0);
        let cap = s - plen;
        let gen = if req.max_new == 0 { cap } else { req.max_new.min(cap) };
        self.slots[r] = Slot {
            active: true,
            id: req.id,
            rng: Rng::new(req.seed),
            // max_new stays 0: the slot's own `end` budget bounds decoding
            // (the session never sees a per-request cap on the serve path)
            opts: DecodeOptions {
                top_k: req.top_k,
                temperature: req.temperature,
                seed: req.seed,
                max_new: 0,
            },
            cursor: plen,
            end: plen + gen,
            prompt_len: plen,
            submitted_at,
            ttft: None,
            deadline_ms: req.deadline_ms,
        };
    }

    /// One decode step for the whole batch (see module docs for the
    /// phases). Allocation-free once the top-k scratch is warm.
    pub fn step(&mut self) -> Result<StepOutcome> {
        self.steps += 1;
        // 1. hot-reload poll — only ever here, between decode steps
        if self.reload.is_some() && self.reload_every > 0 && self.steps % self.reload_every == 0
        {
            self.reload_now();
        }
        let (b, s, vocab) =
            (self.session.rc.model.batch, self.session.rc.model.seq, self.session.rc.model.vocab);
        // 2a. deadline sweep — a slot whose wall-clock budget expired
        // retires *before* the forward with a typed Timeout outcome and
        // whatever it generated so far; its row frees for admission this
        // very step. Row independence means nobody else's tokens move.
        // The `serve.deadline` fault point forces expiry on demand.
        for r in 0..b {
            let sl = &mut self.slots[r];
            if !sl.active || sl.deadline_ms == 0 {
                continue;
            }
            let elapsed_ms = sl.submitted_at.elapsed().as_millis() as u64;
            if crate::faultpoint!("serve.deadline") || elapsed_ms >= sl.deadline_ms {
                sl.active = false;
                let latency = sl.submitted_at.elapsed().as_secs_f64();
                self.metrics.timeouts += 1;
                self.metrics.push_latency(latency);
                self.completed.push(CompletedRequest {
                    id: sl.id,
                    tokens: self.board[r * s..r * s + sl.cursor].to_vec(),
                    prompt_len: sl.prompt_len,
                    generated: sl.cursor - sl.prompt_len,
                    ttft: sl.ttft.unwrap_or(latency),
                    latency,
                    outcome: RequestOutcome::Timeout,
                });
                crate::fault::record(
                    "serve.deadline",
                    self.steps,
                    "timeout",
                    format!(
                        "request {} exceeded {}ms; returning {} generated tokens",
                        sl.id,
                        sl.deadline_ms,
                        sl.cursor - sl.prompt_len
                    ),
                );
                self.session.release_row(r);
            }
        }
        // 2b. admit queued requests into free slots
        self.cold_rows.clear();
        for r in 0..b {
            if self.slots[r].active {
                continue;
            }
            match self.queue.pop() {
                Some((req, at)) => {
                    self.install(r, req, at);
                    self.cold_rows.push(r);
                }
                None => break,
            }
        }
        // 3. per-row cursors; bail out before the forward if nobody is live
        let mut occupancy = 0usize;
        for (r, sl) in self.slots.iter().enumerate() {
            self.positions[r] = if sl.active { sl.cursor - 1 } else { 0 };
            if sl.active {
                occupancy += 1;
            }
        }
        if occupancy == 0 {
            self.metrics.idle_steps += 1;
            return Ok(StepOutcome::Idle);
        }
        let t0 = Instant::now();
        let prefill = if self.session.incremental() {
            self.session.forward_board_cached(&self.board, &self.positions, &self.cold_rows)?
        } else {
            // full-forward mode: label steps that ingested new prompts as
            // prefill so the metrics split stays meaningful
            self.session.forward_board(&self.board, &self.cold_rows)?;
            !self.cold_rows.is_empty()
        };
        let logits = self.session.logits_rows(&self.positions)?;
        self.retired.clear();
        // 4. per-slot selection + retirement. Inlined (not helper methods)
        // because `logits` keeps `self.session` borrowed; every other
        // field access is disjoint.
        for r in 0..b {
            let sl = &mut self.slots[r];
            if !sl.active {
                continue;
            }
            let lg = &logits[r * vocab..(r + 1) * vocab];
            let tok = pick_token(lg, &sl.opts, &mut sl.rng, &mut self.topk_idx, &mut self.topk_val);
            self.board[r * s + sl.cursor] = tok;
            sl.cursor += 1;
            if sl.ttft.is_none() {
                let t = sl.submitted_at.elapsed().as_secs_f64();
                sl.ttft = Some(t);
                self.metrics.push_ttft(t);
            }
            if sl.cursor >= sl.end {
                sl.active = false;
                let latency = sl.submitted_at.elapsed().as_secs_f64();
                self.metrics.completed += 1;
                self.metrics.push_latency(latency);
                self.completed.push(CompletedRequest {
                    id: sl.id,
                    tokens: self.board[r * s..r * s + sl.cursor].to_vec(),
                    prompt_len: sl.prompt_len,
                    generated: sl.cursor - sl.prompt_len,
                    ttft: sl.ttft.unwrap_or(latency),
                    latency,
                    outcome: RequestOutcome::Done,
                });
                self.retired.push(r);
            }
        }
        // free retired rows' cache columns (after the logits borrow ends)
        for &r in &self.retired {
            self.session.release_row(r);
        }
        self.metrics.tokens_generated += occupancy as u64;
        self.metrics
            .record_step(occupancy, t0.elapsed().as_secs_f64(), self.queue.depth(), prefill);
        Ok(StepOutcome::Decoded(occupancy))
    }

    /// Serve until the queue is closed **and** drained and every slot has
    /// retired. While fully idle, blocks up to `idle_wait` for new work
    /// (so a file-mode CLI run exits promptly once its feeders finish).
    ///
    /// This is the graceful-drain path: after
    /// [`RequestQueue::close`] new submissions are rejected with
    /// [`ServeError::Closed`] while every request already queued or on the
    /// board runs to completion (or its deadline), so no accepted work is
    /// dropped on shutdown.
    pub fn run(&mut self, idle_wait: Duration) -> Result<()> {
        loop {
            if self.active() == 0 && self.queue.depth() == 0 {
                if self.queue.is_closed() {
                    return Ok(());
                }
                if !self.queue.wait_nonempty(idle_wait) && self.queue.is_closed() {
                    return Ok(());
                }
                continue;
            }
            self.step()?;
        }
    }
}

/// Closed-loop load driver shared by `layertime bench-serve` and the
/// occupancy sweep in `benches/perf_hotpath.rs`: keep `target_occupancy`
/// requests in flight (active + queued) until every request in `requests`
/// has completed, appending results to `completed`.
pub fn drive_load(
    srv: &mut ServeLoop,
    requests: &[GenerateRequest],
    target_occupancy: usize,
    completed: &mut Vec<CompletedRequest>,
) -> Result<()> {
    ensure!(target_occupancy >= 1, "target occupancy must be ≥ 1");
    let total = requests.len();
    let mut next = 0usize;
    let mut done = 0usize;
    while done < total {
        while next < total && srv.active() + srv.queue.depth() < target_occupancy {
            srv.queue
                .submit(requests[next].clone())
                .map_err(|e| anyhow::anyhow!("load driver submit failed: {}", e))?;
            next += 1;
        }
        match srv.step()? {
            StepOutcome::Idle => {
                // only possible if everything in flight retired and the
                // admission window is empty — the next loop refills it
                ensure!(next < total || done == total, "load driver stalled idle");
            }
            StepOutcome::Decoded(_) => {}
        }
        let newly = srv.take_completed();
        done += newly.len();
        completed.extend(newly);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::coordinator::Mgrit;
    use crate::model::{Init, ParamStore};

    fn tiny_lm_session() -> InferSession {
        let mut rc = presets::by_name("gpt").unwrap();
        presets::shrink_for_bench(&mut rc);
        rc.model.n_dec_layers = 6;
        rc.model.buffer_open = 1;
        rc.model.buffer_close = 1;
        let params = ParamStore::init(&rc.model, Init::Default, 3);
        InferSession::from_parts(rc, params, Box::new(Mgrit)).unwrap()
    }

    #[test]
    fn idle_step_runs_no_forward() {
        let mut srv = ServeLoop::new(tiny_lm_session(), 4).unwrap();
        assert_eq!(srv.step().unwrap(), StepOutcome::Idle);
        assert_eq!(srv.metrics.idle_steps, 1);
        assert_eq!(srv.metrics.decode_steps, 0);
        assert_eq!(srv.session().core_builds(), 0, "idle steps must not touch the solver");
    }

    #[test]
    fn single_request_completes_with_budget() {
        let mut srv = ServeLoop::new(tiny_lm_session(), 4).unwrap();
        let req = GenerateRequest { max_new: 3, ..GenerateRequest::greedy(7, vec![1, 2]) };
        srv.submit(req).unwrap();
        let mut steps = 0;
        while srv.active() > 0 || srv.queue().depth() > 0 {
            srv.step().unwrap();
            steps += 1;
            assert!(steps < 100, "request never retired");
        }
        let done = srv.take_completed();
        assert_eq!(done.len(), 1);
        let d = &done[0];
        assert_eq!(d.id, 7);
        assert_eq!(d.prompt_len, 2);
        assert_eq!(d.generated, 3, "max_new bounds the budget");
        assert_eq!(d.tokens.len(), 5);
        assert_eq!(&d.tokens[..2], &[1, 2], "prompt echoed");
        assert!(d.ttft > 0.0 && d.latency >= d.ttft);
        assert_eq!(srv.metrics.completed, 1);
        assert_eq!(srv.metrics.tokens_generated, 3);
        assert_eq!(srv.metrics.peak_occupancy, 1);
    }

    #[test]
    fn max_new_zero_fills_the_window() {
        let mut srv = ServeLoop::new(tiny_lm_session(), 4).unwrap();
        let s = srv.session().rc.model.seq;
        srv.submit(GenerateRequest::greedy(0, vec![3])).unwrap();
        while srv.active() > 0 || srv.queue().depth() > 0 {
            srv.step().unwrap();
        }
        let done = srv.take_completed();
        assert_eq!(done[0].tokens.len(), s);
        assert_eq!(done[0].generated, s - 1);
    }

    #[test]
    fn steps_split_into_prefill_and_decode() {
        let mut srv = ServeLoop::new(tiny_lm_session(), 8).unwrap();
        srv.submit(GenerateRequest { max_new: 4, ..GenerateRequest::greedy(1, vec![1, 2]) })
            .unwrap();
        srv.step().unwrap(); // the join makes this a prefill step
        assert_eq!(srv.metrics.prefill_steps, 1);
        srv.step().unwrap(); // warm cache, no joiners → pure decode
        assert_eq!(srv.metrics.prefill_steps, 1);
        assert_eq!(srv.metrics.decode_steps, 2);
        // a mid-flight joiner forces another prefill step
        srv.submit(GenerateRequest { max_new: 2, ..GenerateRequest::greedy(2, vec![3]) })
            .unwrap();
        srv.step().unwrap();
        assert_eq!(srv.metrics.prefill_steps, 2);
        let mut steps = 0;
        while srv.active() > 0 {
            srv.step().unwrap();
            steps += 1;
            assert!(steps < 100, "requests never retired");
        }
        assert!(srv.metrics.decode_tokens_per_sec() > 0.0, "pure decode steps must register");
        assert_eq!(srv.take_completed().len(), 2);
    }

    #[test]
    fn drive_load_completes_more_requests_than_slots() {
        let mut srv = ServeLoop::new(tiny_lm_session(), 8).unwrap();
        let b = srv.session().rc.model.batch;
        let requests: Vec<GenerateRequest> = (0..2 * b as u64 + 1)
            .map(|i| GenerateRequest { max_new: 2, ..GenerateRequest::greedy(i, vec![i as i32]) })
            .collect();
        let mut completed = Vec::new();
        drive_load(&mut srv, &requests, b, &mut completed).unwrap();
        assert_eq!(completed.len(), requests.len());
        let mut ids: Vec<u64> = completed.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..2 * b as u64 + 1).collect::<Vec<_>>());
        assert!(srv.metrics.peak_occupancy <= b);
        assert!(srv.metrics.mean_occupancy() > 1.0, "slots should overlap in flight");
    }

    #[test]
    fn expired_deadline_retires_request_with_typed_timeout() {
        let mut srv = ServeLoop::new(tiny_lm_session(), 4).unwrap();
        let req = GenerateRequest {
            max_new: 5,
            deadline_ms: 1,
            ..GenerateRequest::greedy(9, vec![1, 2])
        };
        srv.submit(req).unwrap();
        srv.step().unwrap(); // admits + decodes one token
        std::thread::sleep(Duration::from_millis(5)); // let the 1 ms budget lapse
        srv.step().unwrap(); // deadline sweep retires the slot
        let done = srv.take_completed();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].outcome, RequestOutcome::Timeout);
        assert_eq!(done[0].generated, 1, "tokens so far come back with the timeout");
        assert_eq!(&done[0].tokens[..2], &[1, 2]);
        assert_eq!(srv.metrics.timeouts, 1);
        assert_eq!(srv.metrics.completed, 0, "timeouts are not counted as completions");
        assert_eq!(srv.active(), 0, "the slot is free for the next occupant");
    }

    #[test]
    fn serve_rejects_non_lm_sessions() {
        let mut rc = presets::by_name("vit").unwrap();
        presets::shrink_for_bench(&mut rc);
        let params = ParamStore::init(&rc.model, Init::Default, 1);
        let session = InferSession::from_parts(rc, params, Box::new(Mgrit)).unwrap();
        assert!(ServeLoop::new(session, 4).is_err());
    }
}
