//! Bounded MPSC request queue with backpressure.
//!
//! Producers (CLI feeder threads, the `bench-serve` load driver, tests)
//! call [`RequestQueue::submit`] — non-blocking, rejecting with
//! [`ServeError::QueueFull`] past the high-water mark — or
//! [`RequestQueue::submit_blocking`], which waits for room. The single
//! consumer is the [`super::ServeLoop`] scheduler, which pops at each
//! decode-step boundary. The backing `VecDeque` is preallocated at the
//! configured capacity and submissions are rejected before it would ever
//! grow, so the queue performs **no allocations after construction** —
//! part of the steady-state allocation-free contract pinned by
//! `rust/tests/alloc_audit.rs`.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{GenerateRequest, ServeError};

/// Counters the queue keeps about its own traffic.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueStats {
    /// Accepted submissions.
    pub submitted: u64,
    /// Rejections due to backpressure.
    pub rejected: u64,
    /// Highest depth ever observed.
    pub peak_depth: usize,
}

struct Inner {
    q: VecDeque<(GenerateRequest, Instant)>,
    closed: bool,
    stats: QueueStats,
}

/// Bounded multi-producer request queue (see module docs).
pub struct RequestQueue {
    capacity: usize,
    /// Longest admissible prompt (`seq − 1`: the window must leave room
    /// for at least one generated token).
    max_prompt: usize,
    inner: Mutex<Inner>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl RequestQueue {
    pub fn new(capacity: usize, max_prompt: usize) -> RequestQueue {
        assert!(capacity >= 1, "queue capacity must be ≥ 1");
        assert!(max_prompt >= 1, "max_prompt must be ≥ 1");
        RequestQueue {
            capacity,
            max_prompt,
            inner: Mutex::new(Inner {
                q: VecDeque::with_capacity(capacity),
                closed: false,
                stats: QueueStats::default(),
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    fn validate(&self, req: &GenerateRequest) -> Result<(), ServeError> {
        if req.prompt.is_empty() {
            return Err(ServeError::Invalid("empty prompt".to_string()));
        }
        if req.prompt.len() > self.max_prompt {
            return Err(ServeError::Invalid(format!(
                "prompt of {} tokens exceeds the window's {} admissible positions",
                req.prompt.len(),
                self.max_prompt
            )));
        }
        Ok(())
    }

    /// Non-blocking submit: rejects with [`ServeError::QueueFull`] at the
    /// high-water mark (backpressure — the caller decides whether to
    /// retry, shed, or block via [`RequestQueue::submit_blocking`]).
    pub fn submit(&self, req: GenerateRequest) -> Result<(), ServeError> {
        self.validate(&req)?;
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(ServeError::Closed);
        }
        if inner.q.len() >= self.capacity {
            inner.stats.rejected += 1;
            return Err(ServeError::QueueFull { capacity: self.capacity });
        }
        inner.q.push_back((req, Instant::now()));
        inner.stats.submitted += 1;
        let depth = inner.q.len();
        inner.stats.peak_depth = inner.stats.peak_depth.max(depth);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking submit: waits until the queue has room (or is closed).
    pub fn submit_blocking(&self, req: GenerateRequest) -> Result<(), ServeError> {
        self.validate(&req)?;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(ServeError::Closed);
            }
            if inner.q.len() < self.capacity {
                inner.q.push_back((req, Instant::now()));
                inner.stats.submitted += 1;
                let depth = inner.q.len();
                inner.stats.peak_depth = inner.stats.peak_depth.max(depth);
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Non-blocking pop (scheduler side): the request and its submission
    /// instant, or `None` when the queue is empty.
    pub fn pop(&self) -> Option<(GenerateRequest, Instant)> {
        let mut inner = self.inner.lock().unwrap();
        let item = inner.q.pop_front();
        if item.is_some() {
            drop(inner);
            self.not_full.notify_one();
        }
        item
    }

    /// Block until the queue is non-empty or closed, up to `timeout`.
    /// Returns `true` when something is available to pop.
    pub fn wait_nonempty(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.q.is_empty() {
                return true;
            }
            if inner.closed {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, res) = self.not_empty.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
            if res.timed_out() && inner.q.is_empty() {
                return false;
            }
        }
    }

    /// Close the queue: subsequent submits fail with
    /// [`ServeError::Closed`]; already-queued requests still drain.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        drop(inner);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Current depth (queued, not yet scheduled).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stats(&self) -> QueueStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> GenerateRequest {
        GenerateRequest::greedy(id, vec![1, 2])
    }

    #[test]
    fn backpressure_rejects_past_capacity() {
        let q = RequestQueue::new(2, 4);
        q.submit(req(0)).unwrap();
        q.submit(req(1)).unwrap();
        let err = q.submit(req(2)).unwrap_err();
        assert_eq!(err, ServeError::QueueFull { capacity: 2 });
        assert_eq!(q.depth(), 2);
        let st = q.stats();
        assert_eq!(st.submitted, 2);
        assert_eq!(st.rejected, 1);
        assert_eq!(st.peak_depth, 2);
        // popping frees a slot
        let (popped, _) = q.pop().unwrap();
        assert_eq!(popped.id, 0, "FIFO order");
        q.submit(req(2)).unwrap();
    }

    #[test]
    fn validation_rejects_bad_prompts() {
        let q = RequestQueue::new(4, 3);
        let empty = GenerateRequest::greedy(0, vec![]);
        assert!(matches!(q.submit(empty), Err(ServeError::Invalid(_))));
        let long = GenerateRequest::greedy(1, vec![0; 4]);
        assert!(matches!(q.submit(long), Err(ServeError::Invalid(_))));
        assert_eq!(q.stats().submitted, 0);
    }

    #[test]
    fn close_stops_submissions_but_drains() {
        let q = RequestQueue::new(4, 4);
        q.submit(req(0)).unwrap();
        q.close();
        assert_eq!(q.submit(req(1)).unwrap_err(), ServeError::Closed);
        assert!(q.is_closed());
        assert!(q.pop().is_some(), "queued work still drains after close");
        assert!(q.pop().is_none());
        assert!(!q.wait_nonempty(Duration::from_millis(1)), "closed + empty = no wait");
    }

    #[test]
    fn blocking_submit_wakes_on_pop() {
        let q = Arc::new(RequestQueue::new(1, 4));
        q.submit(req(0)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.submit_blocking(req(1)));
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.pop().is_some());
        t.join().unwrap().unwrap();
        assert_eq!(q.pop().unwrap().0.id, 1);
    }

    #[test]
    fn wait_nonempty_sees_concurrent_submit() {
        let q = Arc::new(RequestQueue::new(2, 4));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.submit(req(0)).unwrap();
        });
        assert!(q.wait_nonempty(Duration::from_secs(5)));
        t.join().unwrap();
    }
}
