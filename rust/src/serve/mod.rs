//! Continuous-batching inference service on the shared forward core.
//!
//! The serve subsystem turns [`crate::infer::InferSession`]'s batched
//! decode path into a multi-tenant serving loop: concurrent users submit
//! [`GenerateRequest`]s (each with its own sampling params and seed) into
//! a bounded [`RequestQueue`]; the [`ServeLoop`] scheduler packs them into
//! the session's fixed `[B, seq]` decode slots **dynamically** — a new
//! prompt joins the running batch at the next decode step, and finished
//! sequences retire without stalling the rest.
//!
//! ## Why this composes with layer-parallel decoding
//!
//! Every kernel on the decode path (row-sliced matmul, per-row softmax /
//! layer-norm, per-sequence attention, the MGRIT restriction /
//! prolongation / FAS pointwise ops) is **batch-row independent**: row
//! `r`'s outputs never read another row's data. The scheduler leans on
//! that three ways:
//!
//! * **Join-mid-flight parity** — when a request is installed into a free
//!   slot, the session resets just that slot's warm-start iterate and
//!   decode-cache row
//!   ([`crate::infer::InferSession::forward_board_cached`]'s `cold_rows`),
//!   so the newcomer solves exactly like its solo cold first step while
//!   the neighbouring rows keep their warm-chained trajectories — and
//!   their K/V cache columns — bit-for-bit.
//! * **Early retirement** — a retired slot's stale board row keeps being
//!   propagated (the batch shape is fixed) but cannot perturb active rows,
//!   so nobody stalls and nobody's tokens change; the slot's cache row is
//!   released for the next occupant.
//! * **Occupancy-independent sampling** — each slot samples from its own
//!   [`crate::util::rng::Rng`] stream seeded by the request (`seed`), so
//!   the same request yields identical tokens at batch occupancy 1 or 8
//!   (pinned by `rust/tests/serve_parity.rs`).
//!
//! ## Hot-reload and observability
//!
//! A [`HotReload`] watcher polls a checkpoint directory between decode
//! steps (never inside one), swapping to the newest **valid** `LTCP` file
//! in place — files failing the FNV-1a checksum are remembered as bad and
//! skipped, not fatal. The training side produces those files via
//! `layertime train --save-every N --keep K` (see
//! [`crate::coordinator::Session::set_autosave`]). [`ServeMetrics`]
//! aggregates queue depth, batch occupancy, time-to-first-token and
//! tokens/sec, serialized through [`crate::util::json`] (and fed as
//! [`crate::util::bench::BenchLog`] rows by `layertime bench-serve`).
//!
//! The steady-state decode step is **allocation-free** like the training
//! step (extended coverage in `rust/tests/alloc_audit.rs`): the board,
//! per-slot cursors/RNGs, logits scratch and solver storage all persist,
//! and the bounded queue never grows past its preallocated capacity.

use std::fmt;

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{self, Json};

mod metrics;
mod queue;
mod reload;
mod scheduler;

pub use metrics::ServeMetrics;
pub use queue::{QueueStats, RequestQueue};
pub use reload::HotReload;
pub use scheduler::{drive_load, ServeLoop, StepOutcome};

/// One user request: a prompt plus per-request sampling parameters.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    /// Caller-chosen request id, echoed on the [`CompletedRequest`].
    pub id: u64,
    /// Prompt token ids; `1 ≤ len ≤ seq − 1` (the model window must leave
    /// room for at least one generated position).
    pub prompt: Vec<i32>,
    /// Number of tokens to generate; `0` = fill the model window.
    pub max_new: usize,
    /// `0` = greedy argmax; `k > 0` = top-k sampling.
    pub top_k: usize,
    /// Softmax temperature for top-k (`T ≤ 0` degenerates to greedy).
    pub temperature: f32,
    /// Per-request sampling seed: the slot's RNG stream is
    /// `Rng::new(seed)` regardless of which slot or batch the request
    /// lands in, which is what makes outputs occupancy-independent.
    pub seed: u64,
    /// Per-request deadline in milliseconds from submission; `0` = none.
    /// A request whose deadline expires before its next decode step is
    /// retired early with [`RequestOutcome::Timeout`] — its tokens so far
    /// are returned, and the neighbouring slots' outputs are untouched
    /// (early retirement is already row-independent).
    pub deadline_ms: u64,
}

impl GenerateRequest {
    /// A greedy request with default everything but the prompt.
    pub fn greedy(id: u64, prompt: Vec<i32>) -> GenerateRequest {
        GenerateRequest {
            id,
            prompt,
            max_new: 0,
            top_k: 0,
            temperature: 1.0,
            seed: id,
            deadline_ms: 0,
        }
    }
}

/// How a request left the board.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Ran to its token budget (or the model window).
    Done,
    /// Retired early because its [`GenerateRequest::deadline_ms`] expired;
    /// `tokens` holds everything generated up to that point.
    Timeout,
}

impl RequestOutcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            RequestOutcome::Done => "done",
            RequestOutcome::Timeout => "timeout",
        }
    }
}

/// A finished request: prompt + generated tokens and per-request timings.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    /// The full board row: prompt followed by the generated tokens.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Number of generated positions (`tokens.len() − prompt_len`).
    pub generated: usize,
    /// Time-to-first-token, seconds from submission.
    pub ttft: f64,
    /// Total latency, seconds from submission to retirement.
    pub latency: f64,
    /// Whether the request ran to completion or was retired by its
    /// deadline.
    pub outcome: RequestOutcome,
}

impl CompletedRequest {
    /// JSON row: `{"id", "prompt_len", "generated", "tokens", "ttft_ms",
    /// "latency_ms", "outcome"}`.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("id", json::int(self.id as i64)),
            ("prompt_len", json::int(self.prompt_len as i64)),
            ("generated", json::int(self.generated as i64)),
            (
                "tokens",
                json::arr(self.tokens.iter().map(|&t| json::int(t as i64)).collect()),
            ),
            ("ttft_ms", json::num(self.ttft * 1e3)),
            ("latency_ms", json::num(self.latency * 1e3)),
            ("outcome", json::s(self.outcome.as_str())),
        ])
    }
}

/// Serve-side request rejection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Backpressure: the queue is at its high-water mark.
    QueueFull { capacity: usize },
    /// The queue was closed (service shutting down).
    Closed,
    /// The request is malformed (empty or over-long prompt, …).
    Invalid(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull { capacity } => {
                write!(f, "request queue full (capacity {}): backpressure, retry later", capacity)
            }
            ServeError::Closed => write!(f, "request queue closed"),
            ServeError::Invalid(msg) => write!(f, "invalid request: {}", msg),
        }
    }
}

impl std::error::Error for ServeError {}

/// Parse a request batch from JSON text: either a top-level array of
/// request objects or `{"requests": [...]}`. Per-object fields: `prompt`
/// (required, array of token ids), `id` (default: array index), `max_new`
/// (default 0 = fill window), `top_k` (default 0 = greedy), `temperature`
/// (default 1.0), `seed` (default: the id), `deadline_ms` (default 0 =
/// no deadline). This is the `layertime serve --requests FILE`
/// file-request format (CI runs it without a network stack).
pub fn requests_from_json(text: &str) -> Result<Vec<GenerateRequest>> {
    let doc = Json::parse(text).context("parsing requests JSON")?;
    let items = match doc.get("requests") {
        Some(r) => r.arr().context("\"requests\" must be an array")?,
        None => doc.arr().context("expected an array of requests or {\"requests\": [...]}")?,
    };
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        ensure!(item.obj().is_some(), "request {} is not an object", i);
        let prompt_json = item
            .get("prompt")
            .with_context(|| format!("request {} is missing \"prompt\"", i))?;
        let prompt_arr = prompt_json
            .arr()
            .with_context(|| format!("request {}: \"prompt\" must be an array", i))?;
        let mut prompt = Vec::with_capacity(prompt_arr.len());
        for t in prompt_arr {
            let v = t
                .int()
                .with_context(|| format!("request {}: prompt tokens must be integers", i))?;
            ensure!(v >= 0, "request {}: negative token id {}", i, v);
            prompt.push(v as i32);
        }
        let id = match item.get("id") {
            Some(v) => v.int().with_context(|| format!("request {}: bad \"id\"", i))? as u64,
            None => i as u64,
        };
        let field_usize = |key: &str| -> Result<usize> {
            match item.get(key) {
                Some(v) => {
                    let n = v.int().with_context(|| format!("request {}: bad \"{}\"", i, key))?;
                    ensure!(n >= 0, "request {}: \"{}\" must be ≥ 0", i, key);
                    Ok(n as usize)
                }
                None => Ok(0),
            }
        };
        let max_new = field_usize("max_new")?;
        let top_k = field_usize("top_k")?;
        let temperature = match item.get("temperature") {
            Some(v) => v
                .num()
                .with_context(|| format!("request {}: bad \"temperature\"", i))?
                as f32,
            None => 1.0,
        };
        let seed = match item.get("seed") {
            Some(v) => v.int().with_context(|| format!("request {}: bad \"seed\"", i))? as u64,
            None => id,
        };
        let deadline_ms = match item.get("deadline_ms") {
            Some(v) => {
                let n =
                    v.int().with_context(|| format!("request {}: bad \"deadline_ms\"", i))?;
                ensure!(n >= 0, "request {}: \"deadline_ms\" must be ≥ 0", i);
                n as u64
            }
            None => 0,
        };
        if prompt.is_empty() {
            bail!("request {}: empty prompt", i);
        }
        out.push(GenerateRequest { id, prompt, max_new, top_k, temperature, seed, deadline_ms });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_parse_with_defaults() {
        let reqs = requests_from_json(r#"[{"prompt": [1, 2, 3]}]"#).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].id, 0);
        assert_eq!(reqs[0].prompt, vec![1, 2, 3]);
        assert_eq!(reqs[0].max_new, 0);
        assert_eq!(reqs[0].top_k, 0);
        assert_eq!(reqs[0].temperature, 1.0);
        assert_eq!(reqs[0].seed, 0, "seed defaults to the id");
        assert_eq!(reqs[0].deadline_ms, 0, "no deadline by default");
    }

    #[test]
    fn requests_parse_full_fields_and_wrapper() {
        let text = r#"{"requests": [
            {"id": 7, "prompt": [4], "max_new": 3, "top_k": 5, "temperature": 0.8,
             "seed": 99, "deadline_ms": 250},
            {"prompt": [1, 1]}
        ]}"#;
        let reqs = requests_from_json(text).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].id, 7);
        assert_eq!(reqs[0].max_new, 3);
        assert_eq!(reqs[0].top_k, 5);
        assert!((reqs[0].temperature - 0.8).abs() < 1e-6);
        assert_eq!(reqs[0].seed, 99);
        assert_eq!(reqs[0].deadline_ms, 250);
        assert_eq!(reqs[1].id, 1, "unnumbered request takes its index");
        assert_eq!(reqs[1].seed, 1);
    }

    #[test]
    fn requests_reject_malformed_input() {
        assert!(requests_from_json("{}").is_err(), "no requests key, not an array");
        assert!(requests_from_json(r#"[{"prompt": []}]"#).is_err(), "empty prompt");
        assert!(requests_from_json(r#"[{"prompt": [-1]}]"#).is_err(), "negative token");
        assert!(requests_from_json(r#"[{"prompt": [1.5]}]"#).is_err(), "fractional token");
        assert!(requests_from_json(r#"[{"id": 1}]"#).is_err(), "missing prompt");
        assert!(
            requests_from_json(r#"[{"prompt": [1], "deadline_ms": -5}]"#).is_err(),
            "negative deadline"
        );
        assert!(requests_from_json("not json").is_err());
    }

    #[test]
    fn completed_request_serializes() {
        let done = CompletedRequest {
            id: 3,
            tokens: vec![1, 2, 9],
            prompt_len: 2,
            generated: 1,
            ttft: 0.002,
            latency: 0.010,
            outcome: RequestOutcome::Done,
        };
        let j = done.to_json();
        assert_eq!(j.get("id").unwrap().int(), Some(3));
        assert_eq!(j.get("tokens").unwrap().arr().unwrap().len(), 3);
        assert_eq!(j.get("generated").unwrap().int(), Some(1));
        assert!((j.get("ttft_ms").unwrap().num().unwrap() - 2.0).abs() < 1e-9);
        assert_eq!(j.get("outcome").unwrap().str(), Some("done"));
        assert_eq!(RequestOutcome::Timeout.as_str(), "timeout");
    }

    #[test]
    fn serve_errors_render() {
        let e = ServeError::QueueFull { capacity: 8 };
        assert!(e.to_string().contains("capacity 8"));
        assert!(ServeError::Closed.to_string().contains("closed"));
        assert!(ServeError::Invalid("x".into()).to_string().contains("x"));
    }
}
