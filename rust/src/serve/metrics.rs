//! Per-request and aggregate serving observability.
//!
//! [`ServeMetrics`] is updated inline by the scheduler: one
//! [`ServeMetrics::record_step`] per decode step (occupancy, wall-clock,
//! queue depth, and whether the step did **prefill** work — prompt ingest
//! for joining requests — or was a pure **decode** step) plus
//! time-to-first-token and latency samples at the per-request milestones.
//! Prefill and decode steps keep separate step-time distributions and the
//! report carries a decode-only tokens/sec next to the aggregate one, so
//! the O(1) steady-state contract of the incremental decode path is
//! observable instead of being averaged away under prompt ingests. Sample
//! vectors are **preallocated at a fixed cap** and stop growing past it
//! (the aggregates keep counting), so recording never allocates at steady
//! state — part of the contract pinned by `rust/tests/alloc_audit.rs`.
//! The JSON report reuses [`Stats::from_samples`] for the latency
//! distributions, matching the fields the bench harness emits.

use std::time::Instant;

use crate::util::bench::Stats;
use crate::util::json::{self, Json};

/// Aggregate serving counters + capped latency samples (see module docs).
#[derive(Debug)]
pub struct ServeMetrics {
    /// Requests retired.
    pub completed: u64,
    /// Tokens emitted across all requests.
    pub tokens_generated: u64,
    /// Decode steps that ran a forward (occupancy ≥ 1).
    pub decode_steps: u64,
    /// The subset of `decode_steps` that did prefill (prompt-ingest) work.
    pub prefill_steps: u64,
    /// Steps skipped because no slot was active.
    pub idle_steps: u64,
    /// Successful checkpoint hot-reloads.
    pub reloads: u64,
    /// Requests retired early by their [`super::GenerateRequest::deadline_ms`]
    /// budget (disjoint from `completed`).
    pub timeouts: u64,
    /// Highest batch occupancy observed.
    pub peak_occupancy: usize,
    /// Highest queue depth observed at a step boundary.
    pub peak_queue_depth: usize,
    occupancy_sum: u64,
    queue_depth_sum: u64,
    /// Wall-clock spent inside decode steps (the tokens/sec denominator).
    decode_secs: f64,
    /// Wall-clock and tokens split by step kind (pure-decode steps only
    /// feed the decode-only throughput).
    decode_only_secs: f64,
    decode_only_tokens: u64,
    /// Capped sample vectors (preallocated; see module docs).
    ttft: Vec<f64>,
    latency: Vec<f64>,
    step_secs: Vec<f64>,
    prefill_step_secs: Vec<f64>,
    decode_step_secs: Vec<f64>,
    cap: usize,
    started: Instant,
}

impl ServeMetrics {
    /// `cap` bounds every sample vector (aggregates are unbounded).
    pub fn with_capacity(cap: usize) -> ServeMetrics {
        ServeMetrics {
            completed: 0,
            tokens_generated: 0,
            decode_steps: 0,
            prefill_steps: 0,
            idle_steps: 0,
            reloads: 0,
            timeouts: 0,
            peak_occupancy: 0,
            peak_queue_depth: 0,
            occupancy_sum: 0,
            queue_depth_sum: 0,
            decode_secs: 0.0,
            decode_only_secs: 0.0,
            decode_only_tokens: 0,
            ttft: Vec::with_capacity(cap),
            latency: Vec::with_capacity(cap),
            step_secs: Vec::with_capacity(cap),
            prefill_step_secs: Vec::with_capacity(cap),
            decode_step_secs: Vec::with_capacity(cap),
            cap,
            started: Instant::now(),
        }
    }

    /// Record a request's time-to-first-token (seconds from submission).
    pub fn push_ttft(&mut self, secs: f64) {
        if self.ttft.len() < self.cap {
            self.ttft.push(secs);
        }
    }

    /// Record a retired request's total latency (seconds).
    pub fn push_latency(&mut self, secs: f64) {
        if self.latency.len() < self.cap {
            self.latency.push(secs);
        }
    }

    /// Record one decode step: how many slots were active, how long the
    /// step took, the queue depth left behind, and whether the step did
    /// prefill (prompt-ingest) work or was a pure decode step.
    pub fn record_step(&mut self, occupancy: usize, took_secs: f64, queue_depth: usize,
                       prefill: bool) {
        self.decode_steps += 1;
        self.occupancy_sum += occupancy as u64;
        self.peak_occupancy = self.peak_occupancy.max(occupancy);
        self.queue_depth_sum += queue_depth as u64;
        self.peak_queue_depth = self.peak_queue_depth.max(queue_depth);
        self.decode_secs += took_secs;
        if self.step_secs.len() < self.cap {
            self.step_secs.push(took_secs);
        }
        if prefill {
            self.prefill_steps += 1;
            if self.prefill_step_secs.len() < self.cap {
                self.prefill_step_secs.push(took_secs);
            }
        } else {
            self.decode_only_secs += took_secs;
            self.decode_only_tokens += occupancy as u64;
            if self.decode_step_secs.len() < self.cap {
                self.decode_step_secs.push(took_secs);
            }
        }
    }

    /// Mean batch occupancy over decode steps (0 before the first step).
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.decode_steps as f64
        }
    }

    /// Mean queue depth at step boundaries.
    pub fn mean_queue_depth(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.queue_depth_sum as f64 / self.decode_steps as f64
        }
    }

    /// Aggregate decode throughput: generated tokens per second of decode
    /// wall-clock (0 before the first step).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_secs <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.decode_secs
        }
    }

    /// Steady-state decode throughput: tokens emitted by pure decode steps
    /// per second of pure-decode wall-clock. Excludes prefill steps, so it
    /// reflects the per-token cost the incremental path's O(1) contract is
    /// about (0 before the first pure decode step).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_only_secs <= 0.0 {
            0.0
        } else {
            self.decode_only_tokens as f64 / self.decode_only_secs
        }
    }

    /// Seconds since the metrics (= the serve loop) started.
    pub fn uptime_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn dist_json(samples: &[f64]) -> Json {
        if samples.is_empty() {
            return Json::Null;
        }
        let st = Stats::from_samples(samples.to_vec());
        json::obj(vec![
            ("mean_ms", json::num(st.mean * 1e3)),
            ("p50_ms", json::num(st.p50 * 1e3)),
            ("p95_ms", json::num(st.p95 * 1e3)),
            ("min_ms", json::num(st.min * 1e3)),
            ("samples", json::int(st.samples as i64)),
        ])
    }

    /// The metrics document `layertime serve --metrics FILE` writes.
    /// Queue counters come from the caller (the queue owns them).
    pub fn to_json(&self, submitted: u64, rejected: u64) -> Json {
        json::obj(vec![
            ("submitted", json::int(submitted as i64)),
            ("rejected", json::int(rejected as i64)),
            ("completed", json::int(self.completed as i64)),
            ("tokens_generated", json::int(self.tokens_generated as i64)),
            ("decode_steps", json::int(self.decode_steps as i64)),
            ("prefill_steps", json::int(self.prefill_steps as i64)),
            ("idle_steps", json::int(self.idle_steps as i64)),
            ("reloads", json::int(self.reloads as i64)),
            ("timeouts", json::int(self.timeouts as i64)),
            ("mean_occupancy", json::num(self.mean_occupancy())),
            ("peak_occupancy", json::int(self.peak_occupancy as i64)),
            ("mean_queue_depth", json::num(self.mean_queue_depth())),
            ("peak_queue_depth", json::int(self.peak_queue_depth as i64)),
            ("tokens_per_sec", json::num(self.tokens_per_sec())),
            ("decode_tokens_per_sec", json::num(self.decode_tokens_per_sec())),
            ("uptime_secs", json::num(self.uptime_secs())),
            ("ttft", ServeMetrics::dist_json(&self.ttft)),
            ("latency", ServeMetrics::dist_json(&self.latency)),
            ("step", ServeMetrics::dist_json(&self.step_secs)),
            ("prefill_step", ServeMetrics::dist_json(&self.prefill_step_secs)),
            ("decode_step", ServeMetrics::dist_json(&self.decode_step_secs)),
            // injected + organic fault events since process start — the
            // serve half of the `--report` fault_events surface
            ("fault_events", crate::fault::events_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_and_caps() {
        let mut m = ServeMetrics::with_capacity(2);
        m.record_step(2, 0.010, 1, true);
        m.record_step(4, 0.030, 3, false);
        m.record_step(3, 0.020, 2, false);
        m.tokens_generated = 9;
        assert_eq!(m.decode_steps, 3);
        assert!((m.mean_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(m.peak_occupancy, 4);
        assert!((m.mean_queue_depth() - 2.0).abs() < 1e-12);
        assert_eq!(m.peak_queue_depth, 3);
        assert!((m.tokens_per_sec() - 9.0 / 0.060).abs() < 1e-6);
        // prefill vs pure-decode split: the decode-only throughput counts
        // only the tokens and wall-clock of the non-prefill steps
        assert_eq!(m.prefill_steps, 1);
        assert_eq!(m.prefill_step_secs.len(), 1);
        assert_eq!(m.decode_step_secs.len(), 2);
        assert!((m.decode_tokens_per_sec() - 7.0 / 0.050).abs() < 1e-6);
        // sample vec capped at 2, aggregates kept counting
        assert_eq!(m.step_secs.len(), 2);
        for _ in 0..5 {
            m.push_ttft(0.001);
            m.push_latency(0.002);
        }
        assert_eq!(m.ttft.len(), 2);
        assert_eq!(m.latency.len(), 2);
    }

    #[test]
    fn json_shape_with_and_without_samples() {
        let empty = ServeMetrics::with_capacity(4);
        let j = empty.to_json(0, 0);
        assert_eq!(j.get("ttft"), Some(&Json::Null), "no samples → null distribution");
        assert_eq!(j.get("tokens_per_sec").unwrap().num(), Some(0.0));
        assert_eq!(j.get("decode_tokens_per_sec").unwrap().num(), Some(0.0));

        let mut m = ServeMetrics::with_capacity(4);
        m.push_ttft(0.004);
        m.push_latency(0.040);
        m.record_step(1, 0.010, 0, true);
        m.record_step(1, 0.002, 0, false);
        m.completed = 1;
        m.tokens_generated = 5;
        let j = m.to_json(3, 1);
        assert_eq!(j.get("submitted").unwrap().int(), Some(3));
        assert_eq!(j.get("rejected").unwrap().int(), Some(1));
        assert_eq!(j.get("completed").unwrap().int(), Some(1));
        assert_eq!(j.get("timeouts").unwrap().int(), Some(0));
        assert!(j.get("fault_events").unwrap().arr().is_some());
        assert_eq!(j.get("prefill_steps").unwrap().int(), Some(1));
        assert_eq!(j.get("prefill_step").unwrap().get("samples").unwrap().int(), Some(1));
        assert_eq!(j.get("decode_step").unwrap().get("samples").unwrap().int(), Some(1));
        assert!((j.get("decode_tokens_per_sec").unwrap().num().unwrap() - 500.0).abs() < 1e-6);
        let ttft = j.get("ttft").unwrap();
        assert!((ttft.get("p50_ms").unwrap().num().unwrap() - 4.0).abs() < 1e-9);
        assert_eq!(ttft.get("samples").unwrap().int(), Some(1));
        // the document round-trips through the writer
        let text = j.to_string_pretty();
        assert_eq!(&Json::parse(&text).unwrap(), &j);
    }
}
