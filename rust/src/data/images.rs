//! Procedural shape images → patch tokens — the ViT/ImageNet analogue.
//!
//! Images are small grayscale grids containing one of `n_classes`
//! procedural patterns (bars, checkers, rings, gradients …) plus noise.
//! Patches are quantized to token ids so the stack reuses the token
//! embedding path; classification is the sequence-level objective, exactly
//! the ViT configuration of the paper (encoder + classifier head).

use super::Batch;
use crate::util::rng::Rng;

pub struct ImageTask {
    /// image side in patches (seq = side²)
    side: usize,
    /// pixels per patch side (patch value = mean intensity, quantized)
    patch: usize,
    vocab: usize,
    n_classes: usize,
}

impl ImageTask {
    /// `seq` must be a perfect square (side² patches per image).
    pub fn new(seq: usize, vocab: usize, n_classes: usize) -> ImageTask {
        let side = (seq as f64).sqrt() as usize;
        assert_eq!(side * side, seq, "seq must be a square number of patches");
        ImageTask { side, patch: 4, vocab, n_classes: n_classes.min(8) }
    }

    /// Render one image into a caller-owned pixel buffer (resized in
    /// place; allocation-free once the capacity is warm).
    fn render_into(&self, class: usize, rng: &mut Rng, img: &mut Vec<f32>) {
        let n = self.side * self.patch;
        img.clear();
        img.resize(n * n, 0.0);
        let phase = rng.range(4) as f32;
        for y in 0..n {
            for x in 0..n {
                let (fx, fy) = (x as f32 / n as f32, y as f32 / n as f32);
                let v = match class % 8 {
                    0 => if ((x as f32 / 4.0 + phase) as usize) % 2 == 0 { 1.0 } else { 0.0 }, // v-bars
                    1 => if ((y as f32 / 4.0 + phase) as usize) % 2 == 0 { 1.0 } else { 0.0 }, // h-bars
                    2 => if ((x / 4 + y / 4) % 2) == 0 { 1.0 } else { 0.0 },                   // checker
                    3 => fx,                                                                    // grad x
                    4 => fy,                                                                    // grad y
                    5 => {
                        let r = ((fx - 0.5).powi(2) + (fy - 0.5).powi(2)).sqrt();
                        if (r * 8.0) as usize % 2 == 0 { 1.0 } else { 0.0 }                    // rings
                    }
                    6 => if (fx - fy).abs() < 0.2 { 1.0 } else { 0.0 },                        // diagonal
                    _ => if fx + fy < 1.0 { 1.0 } else { 0.0 },                                // triangle
                };
                img[y * n + x] = v + 0.15 * rng.normal();
            }
        }
    }

    /// Patch-tokenized classification batch (labels in `labels`).
    pub fn batch(&self, rng: &mut Rng, batch: usize) -> Batch {
        let seq = self.side * self.side;
        let mut out = Batch::empty(batch, seq);
        let mut img = Vec::new();
        self.batch_into(rng, batch, &mut out.tokens, &mut out.labels, &mut img);
        out
    }

    /// Buffer-reusing classification batch: token/label buffers are
    /// refilled in place; `img` is the reusable pixel scratch one image
    /// renders into. Identical rng consumption and values to
    /// [`ImageTask::batch`].
    pub fn batch_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        tokens: &mut Vec<i32>,
        labels: &mut Vec<i32>,
        img: &mut Vec<f32>,
    ) {
        let seq = self.side * self.side;
        tokens.clear();
        tokens.resize(batch * seq, 0);
        labels.clear();
        labels.resize(batch, 0);
        let n = self.side * self.patch;
        for bi in 0..batch {
            let class = rng.range(self.n_classes);
            labels[bi] = class as i32;
            self.render_into(class, rng, img);
            for py in 0..self.side {
                for px in 0..self.side {
                    let mut mean = 0.0f32;
                    for dy in 0..self.patch {
                        for dx in 0..self.patch {
                            mean += img[(py * self.patch + dy) * n + px * self.patch + dx];
                        }
                    }
                    mean /= (self.patch * self.patch) as f32;
                    let tok = ((mean.clamp(0.0, 1.0)) * (self.vocab - 1) as f32).round() as i32;
                    tokens[bi * seq + py * self.side + px] = tok;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let task = ImageTask::new(16, 32, 8);
        let mut rng = Rng::new(1);
        let b = task.batch(&mut rng, 4);
        assert_eq!(b.tokens.len(), 64);
        assert_eq!(b.labels.len(), 4);
        assert!(b.tokens.iter().all(|&t| (0..32).contains(&t)));
        assert!(b.labels.iter().all(|&l| (0..8).contains(&l)));
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean patch-token histograms of two classes must differ
        let task = ImageTask::new(16, 32, 8);
        let mut rng = Rng::new(2);
        let mut per_class: Vec<Vec<f32>> = vec![vec![]; 2];
        for _ in 0..50 {
            let b = task.batch(&mut rng, 1);
            let c = b.labels[0] as usize;
            if c < 2 {
                let mean = b.tokens.iter().map(|&t| t as f32).sum::<f32>() / 16.0;
                per_class[c].push(mean);
            }
        }
        // (weak check: generator runs and produces both classes eventually)
        assert!(per_class[0].len() + per_class[1].len() > 0);
    }

    #[test]
    #[should_panic]
    fn non_square_seq_rejected() {
        ImageTask::new(15, 32, 4);
    }
}
