//! Cipher "translation" pairs — the MT (OPUS de→en) analogue.
//!
//! Source sentences come from a Markov corpus; the target is a
//! deterministic transformation (per-symbol substitution cipher composed
//! with sequence reversal). An encoder-decoder must route information
//! through cross-attention to solve it, exercising exactly the paper's
//! novel encoder-decoder neural-ODE path, and BLEU against the reference
//! is a meaningful metric.

use super::charlm::CharCorpus;
use super::PairBatch;
use crate::util::rng::Rng;

/// Reserved decoder BOS symbol = vocab-1 (sources never emit it).
pub struct TranslateTask {
    corpus: CharCorpus,
    /// substitution cipher over [0, vocab-1)
    subst: Vec<i32>,
    vocab: usize,
    /// whether targets are additionally reversed
    reverse: bool,
}

impl TranslateTask {
    pub fn new(vocab: usize, seed: u64, reverse: bool) -> TranslateTask {
        assert!(vocab >= 4);
        let corpus = CharCorpus::new(vocab - 1, seed, 3); // keep BOS out of sources
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let mut subst: Vec<i32> = (0..(vocab - 1) as i32).collect();
        rng.shuffle(&mut subst);
        TranslateTask { corpus, subst, vocab, reverse }
    }

    pub fn bos(&self) -> i32 {
        (self.vocab - 1) as i32
    }

    /// The ground-truth translation of a source sequence.
    pub fn translate(&self, src: &[i32]) -> Vec<i32> {
        let mut out: Vec<i32> = src.iter().map(|&t| self.subst[t as usize]).collect();
        if self.reverse {
            out.reverse();
        }
        out
    }

    /// Teacher-forced batch: decoder input is BOS + target[..S-1].
    pub fn batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> PairBatch {
        let mut pb = PairBatch {
            src: Vec::new(),
            tgt_in: Vec::new(),
            tgt_out: Vec::new(),
            mask: Vec::new(),
            batch,
            seq,
        };
        self.batch_into(rng, batch, seq, &mut pb.src, &mut pb.tgt_in, &mut pb.tgt_out, &mut pb.mask);
        pb
    }

    /// Buffer-reusing teacher-forced batch: all four `[B·S]` buffers are
    /// refilled in place. The target rows are derived from the source row
    /// already written into `src` (the cipher is per-symbol, the reversal
    /// an index map), so no intermediate sequence is materialized.
    /// Identical rng consumption and values to [`TranslateTask::batch`].
    #[allow(clippy::too_many_arguments)]
    pub fn batch_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        seq: usize,
        src: &mut Vec<i32>,
        tgt_in: &mut Vec<i32>,
        tgt_out: &mut Vec<i32>,
        mask: &mut Vec<f32>,
    ) {
        src.clear();
        src.resize(batch * seq, 0);
        tgt_in.clear();
        tgt_in.resize(batch * seq, 0);
        tgt_out.clear();
        tgt_out.resize(batch * seq, 0);
        mask.clear();
        mask.resize(batch * seq, 1.0);
        for bi in 0..batch {
            let row = bi * seq;
            self.corpus.sample_into_slice(rng, &mut src[row..row + seq]);
            for t in 0..seq {
                let s = if self.reverse { seq - 1 - t } else { t };
                tgt_out[row + t] = self.subst[src[row + s] as usize];
            }
            for t in 0..seq {
                tgt_in[row + t] = if t == 0 { self.bos() } else { tgt_out[row + t - 1] };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn translation_is_bijective_per_symbol() {
        let t = TranslateTask::new(16, 3, false);
        let src: Vec<i32> = (0..15).collect();
        let out = t.translate(&src);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, src);
    }

    #[test]
    fn reverse_mode_reverses() {
        let t = TranslateTask::new(16, 3, true);
        let tf = TranslateTask::new(16, 3, false);
        let src = vec![1, 2, 3, 4];
        let mut fwd = tf.translate(&src);
        fwd.reverse();
        assert_eq!(t.translate(&src), fwd);
    }

    #[test]
    fn teacher_forcing_layout() {
        let t = TranslateTask::new(16, 4, false);
        let mut rng = Rng::new(1);
        let b = t.batch(&mut rng, 2, 8);
        for bi in 0..2 {
            assert_eq!(b.tgt_in[bi * 8], t.bos());
            for s in 1..8 {
                assert_eq!(b.tgt_in[bi * 8 + s], b.tgt_out[bi * 8 + s - 1]);
            }
            // targets are the exact translation of the source row
            let src: Vec<i32> = (0..8).map(|s| b.src[bi * 8 + s]).collect();
            let want = t.translate(&src);
            let got: Vec<i32> = (0..8).map(|s| b.tgt_out[bi * 8 + s]).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn sources_never_use_bos() {
        let t = TranslateTask::new(16, 5, false);
        let mut rng = Rng::new(2);
        let b = t.batch(&mut rng, 4, 32);
        assert!(b.src.iter().all(|&s| s != t.bos()));
    }
}
