//! Order-2 Markov character corpus — the pre-training substrate for the
//! BERT-MLM and GPT-LM analogues.
//!
//! A random (but seed-deterministic) sparse order-2 transition table over
//! `vocab` symbols generates text with real sequential structure: an LM
//! that learns the table reaches substantially lower loss than the unigram
//! entropy, so loss curves have the paper's familiar plateau-then-drop
//! shape. Train/val streams are disjoint by seed.

use super::Batch;
use crate::util::rng::Rng;

/// Markov corpus generator + batchers for LM and MLM objectives.
pub struct CharCorpus {
    vocab: usize,
    /// order-1 transitions: table1[b] -> weights over next symbol.
    /// Learnable from the current token alone (head-only gain, fast early
    /// loss drop — gives curves the paper's plateau-then-drop shape).
    table1: Vec<Vec<f32>>,
    /// order-2 refinement: table[a*vocab + b] -> weights over next symbol.
    /// Requires attention over the previous token (the slow, deep gain).
    table: Vec<Vec<f32>>,
    /// mixture weight of the order-2 component.
    mix2: f32,
}

impl CharCorpus {
    /// Build a corpus model. `branch` controls how peaked transitions are
    /// (small branch = more learnable structure).
    pub fn new(vocab: usize, seed: u64, branch: usize) -> CharCorpus {
        let mut rng = Rng::new(seed ^ 0x1234_5678);
        let mut sparse = |n_rows: usize| -> Vec<Vec<f32>> {
            (0..n_rows)
                .map(|_| {
                    // sparse support: `branch` likely successors, rest epsilon
                    let mut w = vec![0.02f32; vocab];
                    for _ in 0..branch.max(1) {
                        w[rng.range(vocab)] += 1.0;
                    }
                    w
                })
                .collect()
        };
        let table1 = sparse(vocab);
        let table = sparse(vocab * vocab);
        CharCorpus { vocab, table1, table, mix2: 0.5 }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Drive the Markov chain for `n` symbols, handing each to `f(i, sym)`
    /// — the allocation-free core of [`CharCorpus::sample`] and the
    /// `_into` batchers (all three consume the identical rng sequence, so
    /// the data stream is independent of which entry point sampled it).
    fn stream_with(&self, rng: &mut Rng, n: usize, mut f: impl FnMut(usize, i32)) {
        let (mut a, mut b) = (rng.range(self.vocab), rng.range(self.vocab));
        for i in 0..n {
            let next = if rng.uniform() < self.mix2 {
                rng.categorical(&self.table[a * self.vocab + b])
            } else {
                rng.categorical(&self.table1[b])
            };
            f(i, next as i32);
            a = b;
            b = next;
        }
    }

    /// Sample a token stream of length n.
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        self.stream_with(rng, n, |_, sym| out.push(sym));
        out
    }

    /// Sample a token stream straight into a caller-owned slice — the
    /// allocation-free form of [`CharCorpus::sample`] (same rng sequence).
    pub fn sample_into_slice(&self, rng: &mut Rng, out: &mut [i32]) {
        self.stream_with(rng, out.len(), |i, sym| out[i] = sym);
    }

    /// Causal LM batch: inputs = tokens, targets = next tokens, full mask.
    pub fn lm_batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> Batch {
        let mut out = Batch::empty(batch, seq);
        self.lm_batch_into(rng, batch, seq, &mut out.tokens, &mut out.targets, &mut out.mask);
        out
    }

    /// Buffer-reusing causal LM batch: refills caller-owned `[B·S]`
    /// buffers in place (resized on first use, allocation-free at steady
    /// state). Identical rng consumption and values to
    /// [`CharCorpus::lm_batch`].
    pub fn lm_batch_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        seq: usize,
        tokens: &mut Vec<i32>,
        targets: &mut Vec<i32>,
        mask: &mut Vec<f32>,
    ) {
        tokens.clear();
        tokens.resize(batch * seq, 0);
        targets.clear();
        targets.resize(batch * seq, 0);
        mask.clear();
        mask.resize(batch * seq, 1.0);
        for bi in 0..batch {
            // the (seq+1)-long stream lands directly in the two rows:
            // element t is token t (t < seq) and target t-1 (t > 0)
            self.stream_with(rng, seq + 1, |t, sym| {
                if t < seq {
                    tokens[bi * seq + t] = sym;
                }
                if t > 0 {
                    targets[bi * seq + t - 1] = sym;
                }
            });
        }
    }

    /// BERT-style MLM batch: `mask_frac` of slots replaced by `mask_id`,
    /// loss only on masked slots (paper uses 20% masking).
    pub fn mlm_batch(
        &self,
        rng: &mut Rng,
        batch: usize,
        seq: usize,
        mask_frac: f32,
        mask_id: i32,
    ) -> Batch {
        let mut out = Batch::empty(batch, seq);
        self.mlm_batch_into(
            rng,
            batch,
            seq,
            mask_frac,
            mask_id,
            &mut out.tokens,
            &mut out.targets,
            &mut out.mask,
        );
        out
    }

    /// Buffer-reusing MLM batch (see [`CharCorpus::lm_batch_into`]): the
    /// clean stream is staged in the `targets` row (where it belongs
    /// anyway), then the masking pass derives `tokens`/`mask` from it —
    /// no scratch, same rng order as the allocating batcher.
    #[allow(clippy::too_many_arguments)]
    pub fn mlm_batch_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        seq: usize,
        mask_frac: f32,
        mask_id: i32,
        tokens: &mut Vec<i32>,
        targets: &mut Vec<i32>,
        mask: &mut Vec<f32>,
    ) {
        tokens.clear();
        tokens.resize(batch * seq, 0);
        targets.clear();
        targets.resize(batch * seq, 0);
        mask.clear();
        mask.resize(batch * seq, 0.0);
        for bi in 0..batch {
            self.stream_with(rng, seq, |t, sym| targets[bi * seq + t] = sym);
            for t in 0..seq {
                let idx = bi * seq + t;
                if rng.uniform() < mask_frac {
                    tokens[idx] = mask_id;
                    mask[idx] = 1.0;
                } else {
                    tokens[idx] = targets[idx];
                }
            }
            // guarantee at least one masked slot per sequence
            if mask[bi * seq..(bi + 1) * seq].iter().all(|&m| m == 0.0) {
                let t = rng.range(seq);
                tokens[bi * seq + t] = mask_id;
                mask[bi * seq + t] = 1.0;
            }
        }
    }

    /// Entropy (nats) of the unigram stationary-ish distribution — an upper
    /// bound reference line for LM loss curves.
    pub fn unigram_entropy(&self, rng: &mut Rng, samples: usize) -> f64 {
        let stream = self.sample(rng, samples);
        let mut counts = vec![0f64; self.vocab];
        for &t in &stream {
            counts[t as usize] += 1.0;
        }
        let total: f64 = counts.iter().sum();
        -counts
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| {
                let p = c / total;
                p * p.ln()
            })
            .sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let c1 = CharCorpus::new(16, 7, 3);
        let c2 = CharCorpus::new(16, 7, 3);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(c1.sample(&mut r1, 64), c2.sample(&mut r2, 64));
    }

    #[test]
    fn lm_batch_targets_are_shifted() {
        let c = CharCorpus::new(16, 7, 3);
        let mut rng = Rng::new(2);
        let b = c.lm_batch(&mut rng, 2, 8);
        assert_eq!(b.tokens.len(), 16);
        // markov property: target at t must equal token at t+1
        for bi in 0..2 {
            for t in 0..7 {
                assert_eq!(b.targets[bi * 8 + t], b.tokens[bi * 8 + t + 1]);
            }
        }
    }

    #[test]
    fn mlm_masks_expected_fraction() {
        let c = CharCorpus::new(16, 7, 3);
        let mut rng = Rng::new(3);
        let b = c.mlm_batch(&mut rng, 8, 64, 0.2, 15);
        let frac = b.mask.iter().sum::<f32>() / b.mask.len() as f32;
        assert!((frac - 0.2).abs() < 0.05, "masked frac {}", frac);
        // masked slots hold the mask id and the original in targets
        for i in 0..b.mask.len() {
            if b.mask[i] == 1.0 {
                assert_eq!(b.tokens[i], 15);
                assert!(b.targets[i] >= 0 && b.targets[i] < 16);
            }
        }
    }

    #[test]
    fn markov_structure_is_learnable() {
        // conditional entropy given 2-gram context must sit well below the
        // unigram entropy — that gap is what the LM learns.
        let c = CharCorpus::new(16, 7, 2);
        let mut rng = Rng::new(4);
        let uni = c.unigram_entropy(&mut rng, 20_000);
        // expected conditional entropy of the order-1 table rows (the part
        // learnable from the current token alone)
        let mut cond = 0.0f64;
        for w in &c.table1 {
            let total: f32 = w.iter().sum();
            let h: f64 = -w
                .iter()
                .map(|&x| {
                    let p = (x / total) as f64;
                    if p > 0.0 { p * p.ln() } else { 0.0 }
                })
                .sum::<f64>();
            cond += h;
        }
        cond /= c.table1.len() as f64;
        assert!(cond < uni - 0.3, "cond {} vs uni {}", cond, uni);
    }

    #[test]
    fn tokens_within_vocab() {
        let c = CharCorpus::new(8, 9, 3);
        let mut rng = Rng::new(5);
        let b = c.lm_batch(&mut rng, 4, 16);
        assert!(b.tokens.iter().all(|&t| (0..8).contains(&t)));
    }
}
