//! Synthetic data pipelines standing in for the paper's corpora
//! (DESIGN.md §Substitutions — C4/OpenWebText/GUM/OPUS/ImageNet are not
//! reachable offline; the paper's claims concern training *dynamics*, which
//! reproduce on any learnable task with the same architectures):
//!
//! * [`charlm`]  — order-2 Markov character corpus (BERT-MLM + GPT-LM);
//! * [`translate`] — deterministic cipher "translation" pairs (MT task);
//! * [`morpho`] — suffix-rule morphological tagging (MC task, GUM analogue);
//! * [`images`] — procedural shape images → patch tokens (ViT analogue).
//!
//! Every generator is deterministic in its seed and splits train/val by
//! construction (disjoint streams), with vocab sizes matching the compiled
//! artifact geometry.

pub mod charlm;
pub mod images;
pub mod morpho;
pub mod translate;

/// One batch of token-level data. Targets/labels semantics depend on task:
/// LM: next token; MLM: original token at masked slots; tagging: class ids.
#[derive(Debug, Clone)]
pub struct Batch {
    /// Input token ids [B, S].
    pub tokens: Vec<i32>,
    /// Target ids [B, S] (LM/MLM/tagging) — empty for classification.
    pub targets: Vec<i32>,
    /// Loss mask [B, S] (1.0 = counted). All-ones for plain LM.
    pub mask: Vec<f32>,
    /// Sequence-level labels [B] (classification) — empty otherwise.
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn empty(batch: usize, seq: usize) -> Batch {
        Batch {
            tokens: vec![0; batch * seq],
            targets: vec![0; batch * seq],
            mask: vec![1.0; batch * seq],
            labels: vec![],
            batch,
            seq,
        }
    }
}

/// Source/target pair batch for the encoder-decoder task.
#[derive(Debug, Clone)]
pub struct PairBatch {
    /// Encoder input [B, S].
    pub src: Vec<i32>,
    /// Decoder input (shifted right, BOS-prefixed) [B, S].
    pub tgt_in: Vec<i32>,
    /// Decoder targets [B, S].
    pub tgt_out: Vec<i32>,
    /// Loss mask over decoder targets [B, S].
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}
