//! Suffix-rule morphological tagging — the MC (GUM corpus) analogue.
//!
//! "Words" are short symbol spans; each word's morphological class is a
//! deterministic function of its final symbols (as inflectional suffixes
//! are in natural language). Every token position is labelled with its
//! word's class, so an encoder must aggregate context to tag correctly —
//! the per-token classification objective of the paper's MC task.

use super::Batch;
use crate::util::rng::Rng;

pub struct MorphoTask {
    vocab: usize,
    n_classes: usize,
    /// class of a word ending in symbol s = suffix_class[s]
    suffix_class: Vec<i32>,
    /// separator symbol (word boundary)
    sep: i32,
}

impl MorphoTask {
    pub fn new(vocab: usize, n_classes: usize, seed: u64) -> MorphoTask {
        assert!(vocab >= 4 && n_classes >= 2);
        let mut rng = Rng::new(seed ^ 0x5EED);
        let suffix_class = (0..vocab).map(|_| rng.range(n_classes) as i32).collect();
        MorphoTask { vocab, n_classes, suffix_class, sep: 0 }
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Tagging batch: tokens + per-token class labels (in `targets`).
    pub fn batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> Batch {
        let mut out = Batch::empty(batch, seq);
        self.batch_into(rng, batch, seq, &mut out.tokens, &mut out.targets);
        out
    }

    /// Buffer-reusing tagging batch: refills caller-owned `[B·S]` buffers
    /// in place (every position is overwritten). Identical rng consumption
    /// and values to [`MorphoTask::batch`].
    pub fn batch_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        seq: usize,
        tokens: &mut Vec<i32>,
        targets: &mut Vec<i32>,
    ) {
        tokens.clear();
        tokens.resize(batch * seq, 0);
        targets.clear();
        targets.resize(batch * seq, 0);
        for bi in 0..batch {
            let mut t = 0;
            while t < seq {
                // word of length 2..5 followed by a separator
                let wlen = (2 + rng.range(4)).min(seq - t);
                let start = t;
                for _ in 0..wlen {
                    tokens[bi * seq + t] = (1 + rng.range(self.vocab - 1)) as i32;
                    t += 1;
                }
                let last = tokens[bi * seq + t - 1];
                let class = self.suffix_class[last as usize];
                for k in start..t {
                    targets[bi * seq + k] = class;
                }
                if t < seq {
                    tokens[bi * seq + t] = self.sep;
                    targets[bi * seq + t] = self.suffix_class[self.sep as usize];
                    t += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_follow_suffix_rule() {
        let task = MorphoTask::new(16, 4, 1);
        let mut rng = Rng::new(2);
        let b = task.batch(&mut rng, 2, 32);
        // scan words: label of every in-word position equals class of the
        // word-final symbol
        for bi in 0..2 {
            let toks = &b.tokens[bi * 32..(bi + 1) * 32];
            let labs = &b.targets[bi * 32..(bi + 1) * 32];
            let mut start = 0;
            for t in 0..32 {
                if toks[t] == 0 || t == 31 {
                    let end = if toks[t] == 0 { t } else { t + 1 };
                    if end > start {
                        let class = task.suffix_class[toks[end - 1] as usize];
                        for k in start..end {
                            assert_eq!(labs[k], class, "pos {} in word [{},{})", k, start, end);
                        }
                    }
                    start = t + 1;
                }
            }
        }
    }

    #[test]
    fn labels_in_range() {
        let task = MorphoTask::new(16, 4, 3);
        let mut rng = Rng::new(4);
        let b = task.batch(&mut rng, 4, 64);
        assert!(b.targets.iter().all(|&c| (0..4).contains(&c)));
        assert!(b.tokens.iter().all(|&t| (0..16).contains(&t)));
    }

    #[test]
    fn task_requires_context() {
        // at least some positions are not word-final -> their class is not a
        // function of their own token, so context is required
        let task = MorphoTask::new(16, 4, 5);
        let mut rng = Rng::new(6);
        let b = task.batch(&mut rng, 8, 64);
        let mut mismatch = 0;
        for i in 0..b.tokens.len() {
            if task.suffix_class[b.tokens[i] as usize] != b.targets[i] {
                mismatch += 1;
            }
        }
        assert!(mismatch > 0, "task degenerate: every label local");
    }
}
