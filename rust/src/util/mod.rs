//! Substrate utilities built in-repo (the offline registry has no serde /
//! clap / criterion / proptest / rand), each unit-tested:
//!
//! * [`json`] — minimal JSON parser + writer (manifest, configs, run logs)
//! * [`rng`] — SplitMix64 PRNG with normal sampling and shuffles
//! * [`cli`] — `--key value` argument parser
//! * [`bench`] — timing harness (warmup, samples, mean/p50/p95)
//! * [`proptest`] — mini property-test driver with seed reporting
//! * [`csv`] — CSV run-log writer
//! * [`table`] — aligned text tables for bench output

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;
