//! CSV run-log writer: every bench/experiment writes its series under
//! `bench_out/` so figures can be re-plotted outside the repo.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create (directories included) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    /// Write one row of already-formatted cells.
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(cells.len(), self.cols, "csv row width mismatch");
        writeln!(self.w, "{}", cells.join(","))
    }

    /// Write one row of f64 values.
    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        let cells: Vec<String> = cells.iter().map(|v| format!("{}", v)).collect();
        self.row(&cells)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

/// Default output directory for bench CSVs (created on demand).
pub fn bench_out(name: &str) -> String {
    format!("bench_out/{}", name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("layertime_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x".into()]).unwrap();
            w.row_f64(&[2.5, 3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines, vec!["a,b", "1,x", "2.5,3"]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
