//! `--key value` / `--flag` argument parser (substrate for `clap`).
//!
//! Supports subcommands, typed getters with defaults, and `--help`
//! generation from registered options. Unknown flags are an error so typos
//! fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: positional args + `--key value` options + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from raw tokens (no program name).
    pub fn parse(tokens: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&tokens).expect("argument parsing is infallible")
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{} expects an integer, got '{}'", name, v)))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{} expects an integer, got '{}'", name, v)))
            .unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{} expects a float, got '{}'", name, v)))
            .unwrap_or(default)
    }

    /// All parsed option keys (for unknown-flag validation by callers).
    pub fn option_keys(&self) -> impl Iterator<Item = &str> {
        self.options.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str()))
    }

    /// Error unless every provided option/flag appears in `known`.
    pub fn validate(&self, known: &[&str]) -> Result<(), String> {
        for k in self.option_keys() {
            if !known.contains(&k) {
                return Err(format!("unknown option --{} (known: {})", k, known.join(", ")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        // NB: bare flags are greedy — a following non-dash token would be
        // consumed as their value, so flags go last (or use --flag=1).
        let a = Args::parse(&toks("train data.txt --layers 64 --cf=4 --verbose")).unwrap();
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get_usize("layers", 0), 64);
        assert_eq!(a.get_usize("cf", 0), 4);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional[1], "data.txt");
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&toks("run")).unwrap();
        assert_eq!(a.get_usize("layers", 8), 8);
        assert_eq!(a.get_f32("lr", 1e-3), 1e-3);
        assert_eq!(a.get_str("preset", "mc"), "mc");
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&toks("x --fast")).unwrap();
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn validate_rejects_unknown() {
        let a = Args::parse(&toks("--layers 4 --bogus 1")).unwrap();
        assert!(a.validate(&["layers"]).is_err());
        assert!(a.validate(&["layers", "bogus"]).is_ok());
    }

    #[test]
    #[should_panic]
    fn typed_getter_panics_on_garbage() {
        let a = Args::parse(&toks("--layers abc")).unwrap();
        a.get_usize("layers", 0);
    }
}
