//! Aligned text tables: the bench harnesses print each paper table/figure
//! as rows the same shape the paper reports.

/// Column-aligned text table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with per-column width = max cell width.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(cell);
                for _ in cell.chars().count()..widths[c] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: fixed-precision float cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{:.*}", prec, v)
}

/// Format helper: integer cell.
pub fn i(v: i64) -> String {
    format!("{}", v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["task", "speedup"]);
        t.row(vec!["BERT".into(), f(3.25, 2)]);
        t.row(vec!["MC-long-name".into(), f(10.0, 2)]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("task"));
        assert!(lines[2].starts_with("BERT"));
        // all data lines align the second column
        let col = lines[2].find("3.25").unwrap();
        assert_eq!(lines[3].find("10.00").unwrap(), col);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f(1.23456, 3), "1.235");
        assert_eq!(i(-7), "-7");
    }
}
