//! SplitMix64 PRNG with gaussian sampling.
//!
//! Substrate replacement for the `rand` crate (unavailable offline).
//! Deterministic and seedable — every experiment in `EXPERIMENTS.md` records
//! its seed; the paper's multi-seed BERT runs (Fig. 4, grey band) are
//! reproduced by sweeping this seed.

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller sample.
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Raw generator state for checkpointing: the SplitMix64 state word and
    /// the cached Box-Muller spare. Restoring via [`Rng::from_parts`]
    /// continues the stream exactly where it left off.
    pub fn state_parts(&self) -> (u64, Option<f32>) {
        (self.state, self.spare)
    }

    /// Rebuild a generator from [`Rng::state_parts`] output. Unlike
    /// [`Rng::new`], the state word is installed verbatim (no seed
    /// scrambling) so the resumed stream is bit-identical.
    pub fn from_parts(state: u64, spare: Option<f32>) -> Rng {
        Rng { state, spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn range(&mut self, n: usize) -> usize {
        assert!(n > 0, "range(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (sin, cos) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare = Some(r * sin);
            return r * cos;
        }
    }

    /// Vector of normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.range(i + 1));
        }
    }

    /// Sample from an unnormalized discrete distribution.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Random alphanumeric char (test-data helper).
    pub fn alnum(&mut self) -> char {
        const CS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
        CS[self.range(CS.len())] as char
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(8); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.03, "var {}", var);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 1.0])] += 1;
        }
        assert!(counts[1] > counts[0] && counts[1] > counts[2]);
        let frac = counts[1] as f64 / 30_000.0;
        assert!((frac - 0.5).abs() < 0.03, "frac {}", frac);
    }

    #[test]
    fn state_parts_resume_bitwise() {
        let mut r = Rng::new(17);
        // consume an odd number of normals so the Box-Muller spare is live
        let _ = r.normal();
        let (state, spare) = r.state_parts();
        assert!(spare.is_some(), "odd normal draw must cache a spare");
        let mut resumed = Rng::from_parts(state, spare);
        let a: Vec<f32> = (0..16).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..16).map(|_| resumed.normal()).collect();
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(r.next_u64(), resumed.next_u64());
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.split();
        let mut b = r.split();
        let xs: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
