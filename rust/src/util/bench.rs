//! Timing harness (substrate for `criterion`, unavailable offline).
//!
//! `BenchRunner` does warmup + fixed-count sampling and reports
//! mean/std/p50/p95 wall-clock per iteration. Used by every
//! `rust/benches/*.rs` harness and by the §Perf pass in EXPERIMENTS.md.
//! [`BenchLog`] collects labelled rows for machine-readable JSON output so
//! the perf trajectory can be tracked across PRs (`perf_hotpath --json`).

use std::time::Instant;

use crate::util::json::{self, Json};

/// Summary statistics over per-iteration wall-clock samples (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub samples: usize,
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            samples: n,
            mean,
            std: var.sqrt(),
            p50: xs[n / 2],
            p95: xs[(n * 95 / 100).min(n - 1)],
            min: xs[0],
        }
    }

    /// Human-readable time with an adaptive unit.
    pub fn fmt_time(secs: f64) -> String {
        if secs >= 1.0 {
            format!("{:.3} s", secs)
        } else if secs >= 1e-3 {
            format!("{:.3} ms", secs * 1e3)
        } else {
            format!("{:.1} µs", secs * 1e6)
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "mean {} ± {} (p50 {}, p95 {}, n={})",
            Stats::fmt_time(self.mean),
            Stats::fmt_time(self.std),
            Stats::fmt_time(self.p50),
            Stats::fmt_time(self.p95),
            self.samples
        )
    }
}

/// Fixed-budget benchmark runner.
pub struct BenchRunner {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 3, samples: 10 }
    }
}

impl BenchRunner {
    pub fn new(warmup: usize, samples: usize) -> Self {
        BenchRunner { warmup, samples }
    }

    /// Time `f`; the closure's return value is black-boxed via `drop`.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut xs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            xs.push(t0.elapsed().as_secs_f64());
        }
        Stats::from_samples(xs)
    }

    /// Time `f` and print a labelled line.
    pub fn report<T, F: FnMut() -> T>(&self, label: &str, f: F) -> Stats {
        let st = self.run(f);
        println!("  {:<38} {}", label, st.summary());
        st
    }
}

/// Labelled benchmark rows, serializable to JSON (`BENCH_*.json`).
#[derive(Debug, Default)]
pub struct BenchLog {
    rows: Vec<(String, Stats)>,
}

impl BenchLog {
    pub fn new() -> BenchLog {
        BenchLog::default()
    }

    /// Record one benchmark row.
    pub fn push(&mut self, label: &str, st: Stats) {
        self.rows.push((label.to_string(), st));
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// `{"rows": [{"label", "ns_per_op", "p50_ns", "p95_ns", "samples"}]}`
    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|(label, st)| {
                json::obj(vec![
                    ("label", json::s(label)),
                    ("ns_per_op", json::num(st.mean * 1e9)),
                    ("p50_ns", json::num(st.p50 * 1e9)),
                    ("p95_ns", json::num(st.p95 * 1e9)),
                    ("samples", json::int(st.samples as i64)),
                ])
            })
            .collect();
        json::obj(vec![("rows", json::arr(rows))])
    }

    /// Write the JSON document to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_log_serializes_rows() {
        let mut log = BenchLog::new();
        assert!(log.is_empty());
        log.push("phi fwd", Stats::from_samples(vec![2e-6; 4]));
        let j = log.to_json();
        let rows = j.obj().unwrap()["rows"].arr().unwrap();
        assert_eq!(rows.len(), 1);
        let row = rows[0].obj().unwrap();
        assert_eq!(row["label"].str().unwrap(), "phi fwd");
        assert!((row["ns_per_op"].num().unwrap() - 2000.0).abs() < 1e-6);
        assert_eq!(row["samples"].int().unwrap(), 4);
    }

    #[test]
    fn stats_of_constant() {
        let s = Stats::from_samples(vec![2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, 2.0);
    }

    #[test]
    fn stats_percentiles_ordered() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn runner_times_work() {
        let r = BenchRunner::new(1, 5);
        let st = r.run(|| {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(st.mean > 0.0);
        assert_eq!(st.samples, 5);
    }

    #[test]
    fn fmt_time_units() {
        assert!(Stats::fmt_time(2.0).ends_with(" s"));
        assert!(Stats::fmt_time(2e-3).ends_with(" ms"));
        assert!(Stats::fmt_time(2e-6).ends_with(" µs"));
    }
}
