//! Mini property-test driver (substrate for the unavailable `proptest`).
//!
//! `forall(name, cases, |rng| { ... })` runs the closure `cases` times with
//! independent deterministic RNG streams; on panic it reports the failing
//! case index + seed so the case can be replayed with `replay`.

use super::rng::Rng;

/// Base seed; change via LAYERTIME_PROP_SEED to explore other streams.
fn base_seed() -> u64 {
    std::env::var("LAYERTIME_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `f` on `cases` independent RNG streams; panic with replay info on failure.
pub fn forall<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{}' failed at case {}/{} (replay seed: {:#x})",
                name, case, cases, seed
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0u64;
        forall("count", 25, |_| {}); // no capture mutation inside catch_unwind
        for _ in 0..25 {
            n += 1;
        }
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fails", 10, |rng| {
            assert!(rng.uniform() < 2.0); // always true
            assert!(rng.uniform() < 0.0); // always false -> panics
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = 0.0;
        replay(42, |rng| first = rng.uniform());
        let mut second = 0.0;
        replay(42, |rng| second = rng.uniform());
        assert_eq!(first, second);
    }
}
