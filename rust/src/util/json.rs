//! Minimal JSON parser/writer.
//!
//! Substrate replacement for `serde_json` (unavailable offline). Supports
//! the full JSON grammar minus exotic escapes (`\uXXXX` is decoded for the
//! BMP only), which covers `artifacts/manifest.json`, config files, and run
//! logs. Numbers are kept as `f64`; integer accessors validate exactness.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Integer accessor; fails if the number is not integral.
    pub fn int(&self) -> Option<i64> {
        let n = self.num()?;
        if n.fract() == 0.0 && n.abs() < 9.0e15 {
            Some(n as i64)
        } else {
            None
        }
    }

    /// `obj["k"]` lookup that threads through `Option`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.obj()?.get(key)
    }

    /// Path lookup: `j.at(&["config", "d_model"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialize to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers so call sites stay terse.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn int(n: i64) -> Json {
    Json::Num(n as f64)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-4.5e2").unwrap(), Json::Num(-450.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).unwrap().arr().unwrap()[2].get("b").unwrap().str(), Some("x"));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn int_accessor_validates() {
        assert_eq!(Json::parse("42").unwrap().int(), Some(42));
        assert_eq!(Json::parse("42.5").unwrap().int(), None);
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::parse("\"caf\\u00e9 \u{1F600}\"").unwrap();
        assert_eq!(j.str(), Some("café \u{1F600}"));
    }

    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range(4) } else { rng.range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.range(2) == 0),
            2 => Json::Num((rng.range(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let n = rng.range(8);
                Json::Str((0..n).map(|_| rng.alnum()).collect())
            }
            4 => {
                let n = rng.range(4);
                Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
            }
            _ => {
                let n = rng.range(4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{}", i), random_json(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }

    #[test]
    fn prop_roundtrip_compact_and_pretty() {
        forall("json-roundtrip", 200, |rng| {
            let j = random_json(rng, 3);
            let c = Json::parse(&j.to_string_compact()).unwrap();
            let p = Json::parse(&j.to_string_pretty()).unwrap();
            assert_eq!(c, j);
            assert_eq!(p, j);
        });
    }
}
