//! Configuration system: typed configs, JSON round-trip, CLI overrides,
//! and presets mirroring the paper's Tables 2-3 (width-scaled; see
//! DESIGN.md §Substitutions).

pub mod presets_mod;

pub use presets_mod as presets;

use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// Which transformer family a run uses (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// Encoder-only (BERT, MC, ViT analogues).
    Encoder,
    /// Decoder-only with causal masking (GPT analogue).
    Decoder,
    /// Encoder-decoder with cross-attention (MT analogue).
    EncDec,
}

impl Arch {
    pub fn as_str(&self) -> &'static str {
        match self {
            Arch::Encoder => "encoder",
            Arch::Decoder => "decoder",
            Arch::EncDec => "encdec",
        }
    }

    pub fn parse(s: &str) -> Option<Arch> {
        match s {
            "encoder" => Some(Arch::Encoder),
            "decoder" => Some(Arch::Decoder),
            "encdec" => Some(Arch::EncDec),
            _ => None,
        }
    }
}

/// Model geometry — must match the artifact manifest when running on XLA.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub arch: Arch,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub n_classes: usize,
    /// Encoder depth N_enc (layers = ODE time-steps).
    pub n_enc_layers: usize,
    /// Decoder depth N_dec (0 unless Arch::{Decoder, EncDec}).
    pub n_dec_layers: usize,
    /// Serial "buffer" layers at the open end (Appendix B).
    pub buffer_open: usize,
    /// Serial "buffer" layers at the close end (Appendix B).
    pub buffer_close: usize,
}

impl ModelConfig {
    /// Flat parameter vector length for one encoder-family layer
    /// (mirrors ref.enc_layout; checked against the manifest at load).
    pub fn p_enc(&self) -> usize {
        let (d, f) = (self.d_model, self.d_ff);
        4 * d * d + 2 * d * f + 5 * d + f
    }

    /// Flat parameter length of one cross-attending decoder layer.
    pub fn p_dec(&self) -> usize {
        self.p_enc() + 2 * self.d_model + 4 * self.d_model * self.d_model
    }

    /// Total ODE time-steps T = N_enc + N_dec (paper eq. 3).
    pub fn total_layers(&self) -> usize {
        self.n_enc_layers + self.n_dec_layers
    }

    /// Layers inside the ParallelNet (excluding serial buffers, Appendix B).
    pub fn parallel_layers(&self) -> usize {
        self.total_layers().saturating_sub(self.buffer_open + self.buffer_close)
    }

    /// Fine-level step size h for the ParallelNet: the paper uses h=1 for
    /// standard runs and h = 1/L_mid when buffers are enabled (Appendix B).
    pub fn fine_h(&self) -> f32 {
        if self.buffer_open + self.buffer_close > 0 {
            1.0 / self.parallel_layers().max(1) as f32
        } else {
            1.0
        }
    }

    /// Flat parameter length of layer `layer` (dec layout past `n_enc` for
    /// EncDec, enc layout otherwise) — the shape contract checkpoints are
    /// validated against.
    pub fn layer_theta_len(&self, layer: usize) -> usize {
        if self.arch == Arch::EncDec && layer >= self.n_enc_layers {
            self.p_dec()
        } else {
            self.p_enc()
        }
    }

    /// Shape of the evolving ODE state for this geometry: `[B, S, D]`, or
    /// the stacked `[2, B, S, D]` for the encoder-decoder architecture.
    /// Propagators mirror this (`Propagator::state_shape`).
    pub fn state_shape(&self) -> Vec<usize> {
        match self.arch {
            Arch::EncDec => vec![2, self.batch, self.seq, self.d_model],
            _ => vec![self.batch, self.seq, self.d_model],
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("arch", json::s(self.arch.as_str())),
            ("vocab", json::int(self.vocab as i64)),
            ("d_model", json::int(self.d_model as i64)),
            ("n_heads", json::int(self.n_heads as i64)),
            ("d_ff", json::int(self.d_ff as i64)),
            ("seq", json::int(self.seq as i64)),
            ("batch", json::int(self.batch as i64)),
            ("n_classes", json::int(self.n_classes as i64)),
            ("n_enc_layers", json::int(self.n_enc_layers as i64)),
            ("n_dec_layers", json::int(self.n_dec_layers as i64)),
            ("buffer_open", json::int(self.buffer_open as i64)),
            ("buffer_close", json::int(self.buffer_close as i64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            arch: Arch::parse(j.get("arch")?.str()?)?,
            vocab: j.get("vocab")?.int()? as usize,
            d_model: j.get("d_model")?.int()? as usize,
            n_heads: j.get("n_heads")?.int()? as usize,
            d_ff: j.get("d_ff")?.int()? as usize,
            seq: j.get("seq")?.int()? as usize,
            batch: j.get("batch")?.int()? as usize,
            n_classes: j.get("n_classes")?.int()? as usize,
            n_enc_layers: j.get("n_enc_layers")?.int()? as usize,
            n_dec_layers: j.get("n_dec_layers")?.int()? as usize,
            buffer_open: j.get("buffer_open").and_then(|v| v.int()).unwrap_or(0) as usize,
            buffer_close: j.get("buffer_close").and_then(|v| v.int()).unwrap_or(0) as usize,
        })
    }
}

/// MGRIT algorithmic parameters (paper §3.2, Table 3).
#[derive(Debug, Clone, PartialEq)]
pub struct MgritConfig {
    /// Coarsening factor c_f (2, 3, 4, 8 in the paper).
    pub cf: usize,
    /// Number of levels L (2 or 3 in the paper; 1 = serial).
    pub levels: usize,
    /// MGRIT iterations for the forward solve (None = serial forward).
    pub fwd_iters: Option<usize>,
    /// MGRIT iterations for the adjoint solve (None = serial backward).
    pub bwd_iters: Option<usize>,
    /// FCF- (true) vs F-relaxation (false). The paper uses F pre-smoothing
    /// in the scaling runs (Table 3) and FCF in the method description.
    pub fcf: bool,
}

impl Default for MgritConfig {
    fn default() -> Self {
        MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true }
    }
}

impl MgritConfig {
    pub fn serial() -> MgritConfig {
        MgritConfig { cf: 2, levels: 1, fwd_iters: None, bwd_iters: None, fcf: true }
    }

    pub fn is_serial(&self) -> bool {
        self.fwd_iters.is_none() && self.bwd_iters.is_none()
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("cf", json::int(self.cf as i64)),
            ("levels", json::int(self.levels as i64)),
            (
                "fwd_iters",
                self.fwd_iters.map(|v| json::int(v as i64)).unwrap_or(Json::Null),
            ),
            (
                "bwd_iters",
                self.bwd_iters.map(|v| json::int(v as i64)).unwrap_or(Json::Null),
            ),
            ("fcf", Json::Bool(self.fcf)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<MgritConfig> {
        let opt = |v: Option<&Json>| -> Option<usize> {
            match v {
                Some(Json::Null) | None => None,
                Some(x) => x.int().map(|i| i as usize),
            }
        };
        Some(MgritConfig {
            cf: j.get("cf")?.int()? as usize,
            levels: j.get("levels")?.int()? as usize,
            fwd_iters: opt(j.get("fwd_iters")),
            bwd_iters: opt(j.get("bwd_iters")),
            fcf: j.get("fcf")?.bool()?,
        })
    }
}

/// Optimizer choice (paper Table 2 uses SGD/Adam/AdamW per task).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptKind {
    Sgd,
    Adam,
    AdamW,
}

impl OptKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            OptKind::Sgd => "sgd",
            OptKind::Adam => "adam",
            OptKind::AdamW => "adamw",
        }
    }

    pub fn parse(s: &str) -> Option<OptKind> {
        match s {
            "sgd" => Some(OptKind::Sgd),
            "adam" => Some(OptKind::Adam),
            "adamw" => Some(OptKind::AdamW),
            _ => None,
        }
    }
}

/// Training-loop parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub weight_decay: f32,
    pub grad_clip: f32,
    pub opt: OptKind,
    pub seed: u64,
    /// Probe the MGRIT indicator every this many batches (paper: ~500).
    pub probe_every: usize,
    /// Evaluate on the validation split every this many steps.
    pub eval_every: usize,
    /// Adaptive controller enabled (§3.2.3).
    pub adaptive: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            lr: 1e-3,
            warmup: 20,
            weight_decay: 0.01,
            grad_clip: 1.0,
            opt: OptKind::Adam,
            seed: 0,
            probe_every: 50,
            eval_every: 25,
            adaptive: true,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("steps", json::int(self.steps as i64)),
            ("lr", json::num(self.lr as f64)),
            ("warmup", json::int(self.warmup as i64)),
            ("weight_decay", json::num(self.weight_decay as f64)),
            ("grad_clip", json::num(self.grad_clip as f64)),
            ("opt", json::s(self.opt.as_str())),
            // the seed is a full-range u64; JSON numbers are f64 and would
            // silently round it, so it travels as a decimal string
            ("seed", json::s(&self.seed.to_string())),
            ("probe_every", json::int(self.probe_every as i64)),
            ("eval_every", json::int(self.eval_every as i64)),
            ("adaptive", Json::Bool(self.adaptive)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<TrainConfig> {
        let seed = match j.get("seed")? {
            Json::Str(s) => s.parse::<u64>().ok()?,
            n => n.int()? as u64,
        };
        Some(TrainConfig {
            steps: j.get("steps")?.int()? as usize,
            lr: j.get("lr")?.num()? as f32,
            warmup: j.get("warmup")?.int()? as usize,
            weight_decay: j.get("weight_decay")?.num()? as f32,
            grad_clip: j.get("grad_clip")?.num()? as f32,
            opt: OptKind::parse(j.get("opt")?.str()?)?,
            seed,
            probe_every: j.get("probe_every")?.int()? as usize,
            eval_every: j.get("eval_every")?.int()? as usize,
            adaptive: j.get("adaptive")?.bool()?,
        })
    }
}

/// The full run description: model + MGRIT + training + parallel topology.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub name: String,
    pub model: ModelConfig,
    pub mgrit: MgritConfig,
    pub train: TrainConfig,
    /// Layer-parallel degree (devices along the layer/time dimension).
    pub lp_degree: usize,
    /// Data-parallel degree (replicas).
    pub dp_degree: usize,
}

impl RunConfig {
    /// Full-run JSON (the checkpoint header payload).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("model", self.model.to_json()),
            ("mgrit", self.mgrit.to_json()),
            ("train", self.train.to_json()),
            ("lp_degree", json::int(self.lp_degree as i64)),
            ("dp_degree", json::int(self.dp_degree as i64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<RunConfig> {
        Some(RunConfig {
            name: j.get("name")?.str()?.to_string(),
            model: ModelConfig::from_json(j.get("model")?)?,
            mgrit: MgritConfig::from_json(j.get("mgrit")?)?,
            train: TrainConfig::from_json(j.get("train")?)?,
            lp_degree: j.get("lp_degree")?.int()? as usize,
            dp_degree: j.get("dp_degree")?.int()? as usize,
        })
    }

    /// Apply `--key value` overrides (the launcher's config surface).
    pub fn apply_args(&mut self, a: &Args) {
        self.model.n_enc_layers = a.get_usize("enc-layers", self.model.n_enc_layers);
        self.model.n_dec_layers = a.get_usize("dec-layers", self.model.n_dec_layers);
        self.model.batch = a.get_usize("batch", self.model.batch);
        self.model.buffer_open = a.get_usize("buffer-open", self.model.buffer_open);
        self.model.buffer_close = a.get_usize("buffer-close", self.model.buffer_close);
        self.mgrit.cf = a.get_usize("cf", self.mgrit.cf);
        self.mgrit.levels = a.get_usize("levels", self.mgrit.levels);
        if let Some(v) = a.get("fwd-iters") {
            self.mgrit.fwd_iters =
                if v == "serial" { None } else { Some(v.parse().expect("--fwd-iters")) };
        }
        if let Some(v) = a.get("bwd-iters") {
            self.mgrit.bwd_iters =
                if v == "serial" { None } else { Some(v.parse().expect("--bwd-iters")) };
        }
        self.train.steps = a.get_usize("steps", self.train.steps);
        self.train.lr = a.get_f32("lr", self.train.lr);
        self.train.seed = a.get_u64("seed", self.train.seed);
        self.lp_degree = a.get_usize("lp", self.lp_degree);
        self.dp_degree = a.get_usize("dp", self.dp_degree);
        if a.has_flag("no-adaptive") {
            self.train.adaptive = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_python_formula() {
        // Mirrors python/tests/test_model.py::test_param_sizes
        let m = presets::mc_tiny().model;
        let (d, f) = (m.d_model, m.d_ff);
        assert_eq!(m.p_enc(), 4 * d * d + 2 * d * f + 5 * d + f);
        assert_eq!(m.p_dec(), m.p_enc() + 2 * d + 4 * d * d);
    }

    #[test]
    fn model_json_roundtrip() {
        let m = presets::mt_small().model;
        let j = m.to_json();
        let m2 = ModelConfig::from_json(&j).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn run_config_json_roundtrip_preserves_the_seed_exactly() {
        let mut rc = presets::gpt_small();
        rc.train.seed = u64::MAX - 12345; // not representable as f64
        rc.mgrit.fwd_iters = None;
        let rc2 = RunConfig::from_json(&rc.to_json()).unwrap();
        assert_eq!(rc, rc2);
        // and through a serialize → parse → deserialize cycle
        let text = rc.to_json().to_string_pretty();
        let rc3 = RunConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(rc, rc3);
    }

    #[test]
    fn layer_theta_len_and_state_shape_follow_the_arch() {
        let m = presets::mt_small().model;
        assert_eq!(m.layer_theta_len(0), m.p_enc());
        assert_eq!(m.layer_theta_len(m.n_enc_layers), m.p_dec());
        assert_eq!(m.state_shape(), vec![2, m.batch, m.seq, m.d_model]);
        let e = presets::mc_tiny().model;
        assert_eq!(e.layer_theta_len(e.total_layers() - 1), e.p_enc());
        assert_eq!(e.state_shape(), vec![e.batch, e.seq, e.d_model]);
    }

    #[test]
    fn mgrit_json_roundtrip_with_serial_forward() {
        let c = MgritConfig { cf: 3, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: false };
        let c2 = MgritConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn buffer_layers_change_fine_h() {
        let mut m = presets::gpt_small().model;
        // paper Appendix B: 20 layers, 2+2 buffers -> middle 16 with dt=1/16
        m.n_dec_layers = 20;
        m.buffer_open = 2;
        m.buffer_close = 2;
        assert_eq!(m.parallel_layers(), 16);
        assert!((m.fine_h() - 1.0 / 16.0).abs() < 1e-7);
        m.buffer_open = 0;
        m.buffer_close = 0;
        assert_eq!(m.fine_h(), 1.0);
    }

    #[test]
    fn cli_overrides() {
        let mut rc = presets::mc_tiny();
        let toks: Vec<String> =
            ["--enc-layers", "128", "--cf", "8", "--fwd-iters", "serial", "--lp", "4"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        rc.apply_args(&Args::parse(&toks).unwrap());
        assert_eq!(rc.model.n_enc_layers, 128);
        assert_eq!(rc.mgrit.cf, 8);
        assert_eq!(rc.mgrit.fwd_iters, None);
        assert_eq!(rc.lp_degree, 4);
    }
}
