//! Task presets mirroring the paper's Tables 2-3, width-scaled to this
//! testbed (1 CPU core) while preserving the paper's depth and MGRIT
//! parameters — depth is the axis the paper studies (DESIGN.md §Substitutions).
//!
//! | Preset      | Paper analogue | Arch     | Depth      | MGRIT (Table 3)   |
//! |-------------|----------------|----------|------------|-------------------|
//! | `bert_deep` | BERT 128L      | encoder  | 128        | cf=4, L=2, 1F/1B  |
//! | `mc_tiny`   | MC (GUM)       | encoder  | 4..64      | cf=8->2, L=2, 2F/1B |
//! | `vit_small` | ViT 32L        | encoder  | 32         | cf=4, serial F/1B |
//! | `mt_small`  | MT (OPUS de-en)| enc-dec  | 6+6        | cf=3, L=2, 3B     |
//! | `gpt_small` | GPT2 20L       | decoder  | 20 (2+2 buf)| cf=4, serial F/1B |

use super::{Arch, MgritConfig, ModelConfig, OptKind, RunConfig, TrainConfig};

/// Default artifact geometry (must match `make artifacts`):
/// vocab=64, d=64, H=4, d_ff=128, seq=32, batch=8, classes=8.
fn artifact_model(arch: Arch) -> ModelConfig {
    ModelConfig {
        arch,
        vocab: 64,
        d_model: 64,
        n_heads: 4,
        d_ff: 128,
        seq: 32,
        batch: 8,
        n_classes: 8,
        n_enc_layers: 8,
        n_dec_layers: 0,
        buffer_open: 0,
        buffer_close: 0,
    }
}

/// BERT pre-training analogue: very deep encoder, MLM objective.
/// Paper: 128 layers, cf=4, L=2, 1 fwd + 1 bwd iteration, AdamW.
pub fn bert_deep() -> RunConfig {
    let mut model = artifact_model(Arch::Encoder);
    model.n_enc_layers = 128;
    RunConfig {
        name: "bert_deep".into(),
        model,
        mgrit: MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true },
        train: TrainConfig {
            steps: 400,
            lr: 3e-4,
            warmup: 40,
            weight_decay: 0.01,
            opt: OptKind::AdamW,
            ..TrainConfig::default()
        },
        lp_degree: 4,
        dp_degree: 1,
    }
}

/// Morphological-classification analogue: shallow encoder, SGD, tagging head.
/// Paper: GUM corpus, 4..1024 layers in scaling studies, cf=2..8, L=2..3.
pub fn mc_tiny() -> RunConfig {
    let mut model = artifact_model(Arch::Encoder);
    model.n_enc_layers = 8;
    RunConfig {
        name: "mc".into(),
        model,
        mgrit: MgritConfig { cf: 2, levels: 2, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true },
        train: TrainConfig {
            steps: 300,
            lr: 5e-2,
            warmup: 0,
            weight_decay: 0.0,
            opt: OptKind::Sgd,
            ..TrainConfig::default()
        },
        lp_degree: 2,
        dp_degree: 1,
    }
}

/// ViT analogue: encoder over procedural image patches, classification head.
/// Paper: 32 layers, serial forward + 1 backward iteration, cf=4, Adam.
pub fn vit_small() -> RunConfig {
    let mut model = artifact_model(Arch::Encoder);
    model.n_enc_layers = 32;
    RunConfig {
        name: "vit".into(),
        model,
        mgrit: MgritConfig { cf: 4, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: true },
        train: TrainConfig {
            steps: 300,
            lr: 1e-3,
            warmup: 20,
            weight_decay: 0.0,
            opt: OptKind::Adam,
            ..TrainConfig::default()
        },
        lp_degree: 2,
        dp_degree: 1,
    }
}

/// Machine-translation analogue: encoder-decoder, cipher translation pairs.
/// Paper: 6+6 layers, cf=3, L=2, serial fwd + 3 bwd iterations, Adam.
pub fn mt_small() -> RunConfig {
    let mut model = artifact_model(Arch::EncDec);
    model.n_enc_layers = 6;
    model.n_dec_layers = 6;
    RunConfig {
        name: "mt".into(),
        model,
        mgrit: MgritConfig { cf: 3, levels: 2, fwd_iters: None, bwd_iters: Some(3), fcf: true },
        train: TrainConfig {
            steps: 400,
            lr: 1e-3,
            warmup: 40,
            weight_decay: 0.0,
            opt: OptKind::Adam,
            ..TrainConfig::default()
        },
        lp_degree: 2,
        dp_degree: 1,
    }
}

/// GPT-2 pre-training analogue: decoder-only char-LM with buffer layers.
/// Paper Appendix B: 20 layers, 2+2 serial buffers, middle 16 with dt=1/16;
/// cf=4, serial forward + 1 backward iteration, AdamW.
pub fn gpt_small() -> RunConfig {
    let mut model = artifact_model(Arch::Decoder);
    model.n_enc_layers = 0;
    model.n_dec_layers = 20;
    model.buffer_open = 2;
    model.buffer_close = 2;
    RunConfig {
        name: "gpt".into(),
        model,
        mgrit: MgritConfig { cf: 4, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: true },
        train: TrainConfig {
            steps: 400,
            lr: 6e-4,
            warmup: 40,
            weight_decay: 0.01,
            opt: OptKind::AdamW,
            ..TrainConfig::default()
        },
        lp_degree: 2,
        dp_degree: 1,
    }
}

/// Look up a preset by name (the CLI surface).
pub fn by_name(name: &str) -> Option<RunConfig> {
    match name {
        "bert" | "bert_deep" => Some(bert_deep()),
        "mc" | "mc_tiny" => Some(mc_tiny()),
        "vit" | "vit_small" => Some(vit_small()),
        "mt" | "mt_small" => Some(mt_small()),
        "gpt" | "gpt_small" => Some(gpt_small()),
        _ => None,
    }
}

/// All preset names (for `--help` and sweeps).
pub const ALL: &[&str] = &["bert_deep", "mc_tiny", "vit_small", "mt_small", "gpt_small"];

/// Shrink a run to bench scale: small width/seq/batch so the paper-shape
/// experiments (Figs. 3-5, 12, Table 1) finish in seconds on one CPU core
/// while keeping the preset's depth structure and MGRIT parameters.
pub fn shrink_for_bench(rc: &mut RunConfig) {
    rc.model.vocab = 32;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 16;
    rc.model.batch = 4;
    rc.model.n_classes = 4;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve() {
        for name in ALL {
            let rc = by_name(name).unwrap();
            assert!(rc.model.total_layers() > 0, "{}", name);
            assert!(rc.mgrit.cf >= 2);
        }
    }

    #[test]
    fn gpt_matches_appendix_b() {
        let rc = gpt_small();
        assert_eq!(rc.model.n_dec_layers, 20);
        assert_eq!(rc.model.parallel_layers(), 16);
        assert!((rc.model.fine_h() - 1.0 / 16.0).abs() < 1e-7);
        assert_eq!(rc.mgrit.fwd_iters, None); // serial forward (Table 3)
        assert_eq!(rc.mgrit.bwd_iters, Some(1));
    }

    #[test]
    fn mt_matches_table3() {
        let rc = mt_small();
        assert_eq!(rc.mgrit.cf, 3);
        assert_eq!(rc.mgrit.bwd_iters, Some(3));
        assert_eq!(rc.model.arch, Arch::EncDec);
        assert_eq!(rc.model.total_layers(), 12);
    }
}
