//! Offline stub of the `xla` (xla-rs) binding surface consumed by
//! `layertime::runtime::engine`.
//!
//! The real crate links the PJRT C API and executes compiled HLO. This
//! stub keeps the workspace buildable and testable in environments
//! without the XLA extension libraries: every entry point that would
//! touch PJRT returns a descriptive error, so `XlaEngine::load` fails
//! fast and all artifact-gated tests/benches skip cleanly (they guard on
//! `artifacts/manifest.json` existing). Swap this path dependency for the
//! real bindings to run the AOT artifacts.
//!
//! Only the API subset `runtime::engine` uses is provided; signatures
//! mirror xla-rs so the swap is a Cargo.toml change, not a code change.

use std::borrow::Borrow;
use std::fmt;

/// Error type (the real bindings surface PJRT status codes).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{}: XLA/PJRT runtime not available — layertime was built against the vendored \
         stub (rust/vendor/xla); link the real xla bindings to execute AOT artifacts",
        what
    )))
}

/// Typed storage behind a [`Literal`].
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn store(data: Vec<Self>) -> Storage;
    fn load(storage: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn store(data: Vec<Self>) -> Storage {
        Storage::F32(data)
    }

    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn store(data: Vec<Self>) -> Storage {
        Storage::I32(data)
    }

    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side typed array (shape + data).
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { storage: T::store(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions.
    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let len = match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        } as i64;
        if n != len {
            return Err(Error(format!("reshape: {} elements into dims {:?}", len, dims)));
        }
        self.dims = dims.to_vec();
        Ok(self)
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.storage).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("decomposing result tuple")
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("creating PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling XLA computation")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing compiled entry point")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching device buffer")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        unavailable(&format!("parsing HLO text {}", path))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[1i32]).reshape(&[7]).is_err());
    }

    #[test]
    fn pjrt_entry_points_fail_fast_with_context() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{}", err).contains("vendored stub"));
    }
}
