//! MGRIT over real transformers: the pure-Rust propagator (always) and the
//! XLA/PJRT propagator (when artifacts are built).
//!
//! Pins the paper's core claims at test scale:
//! * MGRIT forward/adjoint converge to the serial result on a nonlinear
//!   neural-ODE transformer (encoder, decoder-causal, and encoder-decoder);
//! * few-iteration MGRIT yields *inexact but close* gradients (the paper's
//!   working regime);
//! * the XLA and Rust propagators agree through the whole MGRIT stack.

use std::sync::Arc;

use layertime::config::{Arch, MgritConfig, ModelConfig};
use layertime::mgrit::MgritSolver;
use layertime::ode::{shared_params, Propagator, RustPropagator, SharedParams, XlaPropagator};
use layertime::runtime::XlaEngine;
use layertime::tensor::Tensor;
use layertime::util::rng::Rng;

fn model(arch: Arch, n_layers: usize) -> ModelConfig {
    ModelConfig {
        arch,
        vocab: 16,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        seq: 4,
        batch: 2,
        n_classes: 4,
        n_enc_layers: if arch == Arch::EncDec { n_layers / 2 } else { n_layers },
        n_dec_layers: if arch == Arch::EncDec { n_layers / 2 } else { 0 },
        buffer_open: 0,
        buffer_close: 0,
    }
}

fn params(m: &ModelConfig, rng: &mut Rng, std: f32) -> SharedParams {
    let mut v = Vec::new();
    for l in 0..m.total_layers() {
        let len = if m.arch == Arch::EncDec && l >= m.n_enc_layers { m.p_dec() } else { m.p_enc() };
        v.push(rng.normal_vec(len, std));
    }
    shared_params(v)
}

fn mgcfg(cf: usize, levels: usize) -> MgritConfig {
    MgritConfig { cf, levels, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true }
}

#[test]
fn mgrit_forward_converges_on_transformer() {
    for arch in [Arch::Encoder, Arch::Decoder, Arch::EncDec] {
        let m = model(arch, 16);
        let mut rng = Rng::new(7);
        let prop = RustPropagator::new(&m, 0.25, params(&m, &mut rng, 0.1));
        let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let solver = MgritSolver::new(&prop, mgcfg(4, 2));

        let (serial, _) = solver.forward(&z0, None, None, false);
        let (mg, stats) = solver.forward(&z0, Some(6), None, true);
        assert!(
            stats.residuals.last().unwrap() < &1e-3,
            "{:?}: residuals {:?}",
            arch,
            stats.residuals
        );
        let rel = mg.last().unwrap().dist(serial.last().unwrap())
            / serial.last().unwrap().norm().max(1e-9);
        assert!(rel < 1e-3, "{:?}: relative final-state error {}", arch, rel);
    }
}

#[test]
fn mgrit_adjoint_and_gradients_converge_on_transformer() {
    let m = model(Arch::Encoder, 16);
    let mut rng = Rng::new(8);
    let ps = params(&m, &mut rng, 0.1);
    let prop = RustPropagator::new(&m, 0.25, ps);
    let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let ct = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let solver = MgritSolver::new(&prop, mgcfg(4, 2));

    let (states, _) = solver.forward(&z0, None, None, false);
    let (lam_exact, _) = solver.adjoint(&states, &ct, None, false);
    let g_exact = solver.gradients(&states, &lam_exact);

    // converged MGRIT adjoint reproduces exact gradients
    let (lam_mg, _) = solver.adjoint(&states, &ct, Some(6), false);
    let g_mg = solver.gradients(&states, &lam_mg);
    for (a, b) in g_mg.iter().zip(&g_exact) {
        let diff: f32 = a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(diff < 1e-3, "grad diff {}", diff);
    }

    // one-iteration MGRIT adjoint is inexact but close (the paper's regime)
    let (lam_1, _) = solver.adjoint(&states, &ct, Some(1), false);
    let g_1 = solver.gradients(&states, &lam_1);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in g_1.iter().zip(&g_exact) {
        for (x, y) in a.iter().zip(b.iter()) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
    }
    let rel = (num / den.max(1e-30)).sqrt();
    assert!(rel < 0.5, "one-iter gradient relative error {}", rel);
    assert!(rel > 1e-6, "one-iter gradient should be inexact, rel={}", rel);
}

#[test]
fn mgrit_inexact_forward_bias_shrinks_with_iterations() {
    // The paper's premise: iteration count controls the inexactness.
    let m = model(Arch::Decoder, 16);
    let mut rng = Rng::new(9);
    let prop = RustPropagator::new(&m, 0.25, params(&m, &mut rng, 0.1));
    let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let solver = MgritSolver::new(&prop, mgcfg(2, 2));
    let (serial, _) = solver.forward(&z0, None, None, false);
    let exact = serial.last().unwrap();
    let mut prev = f32::INFINITY;
    for k in [1usize, 2, 4] {
        let (mg, _) = solver.forward(&z0, Some(k), None, false);
        let err = mg.last().unwrap().dist(exact);
        assert!(err <= prev * 1.001, "error should shrink: k={} err={} prev={}", k, err, prev);
        prev = err;
    }
}

#[test]
fn xla_propagator_matches_rust_through_mgrit() {
    let dir = std::env::var("LAYERTIME_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Arc::new(XlaEngine::load(&dir).unwrap());
    let mf = engine.manifest();
    let m = ModelConfig {
        arch: Arch::Encoder,
        vocab: mf.cfg("vocab").unwrap(),
        d_model: mf.cfg("d_model").unwrap(),
        n_heads: mf.cfg("n_heads").unwrap(),
        d_ff: mf.cfg("d_ff").unwrap(),
        seq: mf.cfg("seq").unwrap(),
        batch: mf.cfg("batch").unwrap(),
        n_classes: mf.cfg("n_classes").unwrap(),
        n_enc_layers: 8,
        n_dec_layers: 0,
        buffer_open: 0,
        buffer_close: 0,
    };
    let mut rng = Rng::new(10);
    let ps = params(&m, &mut rng, 0.05);
    let xla = XlaPropagator::new(engine, &m, 1.0, ps.clone()).unwrap();
    let rust = RustPropagator::new(&m, 1.0, ps);
    let z0 = Tensor::randn(&mut rng, &xla.state_shape(), 1.0);

    let cfg = mgcfg(4, 2);
    let xs = MgritSolver::new(&xla, cfg.clone());
    let rs = MgritSolver::new(&rust, cfg);

    let (wx, sx) = xs.forward(&z0, Some(2), None, true);
    let (wr, sr) = rs.forward(&z0, Some(2), None, true);
    for (a, b) in wx.iter().zip(&wr) {
        assert!(a.allclose(b, 1e-3, 1e-3), "state diff {}", a.max_abs_diff(b));
    }
    // identical algorithm => identical residual history, up to fp noise
    // (skip once residuals are at roundoff level)
    for (a, b) in sx.residuals.iter().zip(&sr.residuals) {
        if *b > 1e-4 {
            assert!((a - b).abs() / b < 1e-2, "residuals {} vs {}", a, b);
        }
    }

    // adjoint path too
    let ct = Tensor::randn(&mut rng, &xla.state_shape(), 1.0);
    let (lx, _) = xs.adjoint(&wx, &ct, Some(1), false);
    let (lr, _) = rs.adjoint(&wr, &ct, Some(1), false);
    for (a, b) in lx.iter().zip(&lr) {
        assert!(a.allclose(b, 1e-3, 1e-3), "lambda diff {}", a.max_abs_diff(b));
    }
    let gx = xs.gradients(&wx, &lx);
    let gr = rs.gradients(&wr, &lr);
    for (a, b) in gx.iter().zip(&gr) {
        let diff: f32 = a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(diff < 5e-3, "grad diff {}", diff);
    }
}

#[test]
fn encdec_mgrit_full_pipeline() {
    // The paper's novel encoder-decoder neural-ODE: stacked state through
    // MGRIT end to end with gradient extraction.
    let m = model(Arch::EncDec, 12);
    let mut rng = Rng::new(11);
    let prop = RustPropagator::new(&m, 0.3, params(&m, &mut rng, 0.1));
    let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let ct = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let solver = MgritSolver::new(&prop, MgritConfig {
        cf: 3,
        levels: 2,
        fwd_iters: Some(3),
        bwd_iters: Some(3),
        fcf: true,
    });
    let (states, fs) = solver.forward(&z0, Some(3), None, true);
    assert!(fs.residuals.last().unwrap() < &1e-2);
    let (lams, _) = solver.adjoint(&states, &ct, Some(3), false);
    let grads = solver.gradients(&states, &lams);
    assert_eq!(grads.len(), 12);
    assert_eq!(grads[0].len(), m.p_enc());
    assert_eq!(grads[11].len(), m.p_dec());
    assert!(grads.iter().all(|g| g.iter().all(|v| v.is_finite())));
    assert!(grads.iter().any(|g| g.iter().any(|v| v.abs() > 0.0)));
}
