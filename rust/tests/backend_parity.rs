//! Backend parity: the Session API's acceptance property.
//!
//! From one seed on the `mc` preset, the `Serial`, `Mgrit` (with the
//! iteration budget in exact mode), and `ThreadedMgrit` (workers ∈
//! {1, 2, 4}) backends must produce **bitwise-identical** losses and
//! gradients — threading and backend plumbing may never change a single
//! bit of the training trajectory. Inexact MGRIT (finite iteration budget)
//! must likewise be bitwise invariant across worker counts, and converge
//! to the serial trajectory as the budget grows.
//!
//! Since the zero-allocation hot-path rework, `ThreadedMgrit` solves here
//! run their relaxation sweeps on the backend's **persistent worker pool**
//! and every state update flows through the buffer-reusing
//! `step_into`/`adjoint_step_into` entry points — so these properties now
//! pin the pool schedule and the `*_into` math against the serial oracle.

use layertime::config::{presets, MgritConfig, RunConfig};
use layertime::coordinator::{
    backend_for_workers, Backend, Mgrit, Serial, Session, Task, ThreadedMgrit,
};
use layertime::mgrit::MgritSolver;
use layertime::ode::{shared_params, Propagator, RustPropagator};
use layertime::tensor::Tensor;
use layertime::util::proptest::forall;
use layertime::util::rng::Rng;

/// The `mc` preset shrunk to parity-test scale.
fn tiny_mc(seed: u64, cf: usize, fwd: Option<usize>, bwd: Option<usize>) -> RunConfig {
    let mut rc = presets::by_name("mc").unwrap();
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_enc_layers = 8;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf, levels: 2, fwd_iters: fwd, bwd_iters: bwd, fcf: true };
    rc.train.steps = 3;
    rc.train.eval_every = 100;
    rc.train.probe_every = 0;
    rc.train.adaptive = false;
    rc.train.warmup = 0;
    rc.train.seed = seed;
    rc
}

/// Train `steps` steps; return (per-step loss bits, final layer params).
fn run(backend: Box<dyn Backend>, rc: RunConfig, steps: usize) -> (Vec<u32>, Vec<Vec<f32>>) {
    let mut s = Session::builder().config(rc).task(Task::Tag).backend(backend).build().unwrap();
    let losses: Vec<u32> = (0..steps).map(|_| s.train_step().loss.to_bits()).collect();
    let layers = s.params.layers.read().unwrap().clone();
    (losses, layers)
}

fn assert_identical(tag: &str, a: &(Vec<u32>, Vec<Vec<f32>>), b: &(Vec<u32>, Vec<Vec<f32>>)) {
    assert_eq!(a.0, b.0, "{}: losses must be bitwise identical", tag);
    assert_eq!(a.1.len(), b.1.len());
    for (l, (x, y)) in a.1.iter().zip(&b.1).enumerate() {
        assert_eq!(x, y, "{}: layer {} gradients/params must be bitwise identical", tag, l);
    }
}

#[test]
fn prop_exact_backends_are_bitwise_identical() {
    // Serial ≡ Mgrit(iters → exact/None) ≡ ThreadedMgrit{1,2,4}(exact):
    // all three backends reduce to the same exact propagation.
    forall("exact-backend-parity", 4, |rng| {
        let seed = rng.range(1000) as u64;
        let rc = tiny_mc(seed, 2, None, None);
        let baseline = run(Box::new(Serial), rc.clone(), 3);
        let mgrit = run(Box::new(Mgrit), rc.clone(), 3);
        assert_identical("serial-vs-mgrit", &baseline, &mgrit);
        for workers in [1usize, 2, 4] {
            let thr = run(Box::new(ThreadedMgrit::new(workers)), rc.clone(), 3);
            assert_identical("serial-vs-threaded", &baseline, &thr);
        }
    });
}

#[test]
fn prop_threaded_mgrit_is_bitwise_identical_to_single_threaded() {
    // The real-thread guarantee on the inexact (iterative) path: the
    // relaxation schedule is invariant under slab decomposition.
    forall("threaded-mgrit-parity", 4, |rng| {
        let seed = rng.range(1000) as u64;
        let cf = [2usize, 4][rng.range(2)];
        let rc = tiny_mc(seed, cf, Some(2), Some(1));
        let single = run(Box::new(Mgrit), rc.clone(), 3);
        for workers in [1usize, 2, 4] {
            let thr = run(Box::new(ThreadedMgrit::new(workers)), rc.clone(), 3);
            assert_identical("mgrit-vs-threaded", &single, &thr);
        }
    });
}

#[test]
fn prop_cached_cores_match_fresh_cores_across_adaptive_transitions() {
    // The persistent-context acceptance property: a run whose controller
    // fires IncreaseIters and then SwitchSerial mid-run produces bitwise
    // identical curves whether the MGRIT hierarchies are cached across
    // steps (the steady-state path) or rebuilt fresh before every step
    // (`invalidate_solve_context`), for 1/2/4 workers. The transitions are
    // driven through the controller's public API so both arms see the
    // exact same config mutations at the exact same steps.
    forall("cached-vs-fresh-adaptive", 3, |rng| {
        let seed = rng.range(1000) as u64;
        let rc = tiny_mc(seed, 2, Some(1), Some(1));
        for workers in [1usize, 2, 4] {
            let mk = || {
                Session::builder()
                    .config(rc.clone())
                    .task(Task::Tag)
                    .backend(backend_for_workers(workers))
                    .build()
                    .unwrap()
            };
            let mut cached = mk();
            let mut fresh = mk();
            let (mut curve_c, mut curve_f) = (Vec::new(), Vec::new());
            for step in 0..6 {
                if step == 2 {
                    // ρ = 0.95 ∈ [rho_grow, rho_switch): IncreaseIters —
                    // iteration counts double, the cached cores must be
                    // reused as-is
                    cached.controller.observe(Some(0.95), None, &mut cached.rc.mgrit);
                    fresh.controller.observe(Some(0.95), None, &mut fresh.rc.mgrit);
                    assert_eq!(cached.rc.mgrit.fwd_iters, Some(2));
                }
                if step == 4 {
                    // SwitchSerial: the cached cores are bypassed
                    cached.controller.force_serial(&mut cached.rc.mgrit);
                    fresh.controller.force_serial(&mut fresh.rc.mgrit);
                }
                fresh.invalidate_solve_context();
                curve_c.push(cached.train_step().loss.to_bits());
                curve_f.push(fresh.train_step().loss.to_bits());
            }
            assert_eq!(curve_c, curve_f, "loss curves, workers={}", workers);
            let a = cached.params.layers.read().unwrap().clone();
            let b = fresh.params.layers.read().unwrap().clone();
            for (l, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x, y, "layer {} params, workers={}", l, workers);
            }
            assert!(cached.controller.is_serial());
            assert_eq!(
                cached.solve_core_builds(),
                2,
                "cached arm must keep its two cores across both transitions (workers={})",
                workers
            );
            assert!(
                !cached.has_warm_iterate(),
                "the warm iterate must be dropped at the serial switch (workers={})",
                workers
            );
        }
    });
}

#[test]
fn converged_mgrit_matches_serial_dynamics() {
    // FCF-MGRIT is a direct method after enough cycles: with the budget
    // cranked up, the (inexact-by-construction) backends land on the
    // serial trajectory to fp tolerance.
    let rc_serial = tiny_mc(7, 2, None, None);
    let rc_mg = tiny_mc(7, 2, Some(8), Some(8));
    let (a, _) = run(Box::new(Serial), rc_serial, 3);
    let (b, _) = run(Box::new(Mgrit), rc_mg, 3);
    for (x, y) in a.iter().zip(&b) {
        let (x, y) = (f32::from_bits(*x), f32::from_bits(*y));
        assert!((x - y).abs() < 5e-3 * (1.0 + x.abs()), "serial {} vs mgrit {}", x, y);
    }
}

#[test]
fn solver_level_losses_and_gradients_bitwise_across_workers() {
    // Below the Session layer: forward states, adjoint λ, and per-layer
    // parameter gradients out of the MGRIT solver itself are bitwise
    // invariant under the worker count — forward AND adjoint sweeps.
    let m = {
        let mut m = presets::by_name("mc").unwrap().model;
        m.vocab = 16;
        m.d_model = 16;
        m.n_heads = 2;
        m.d_ff = 32;
        m.seq = 8;
        m.batch = 2;
        m.n_enc_layers = 8;
        m
    };
    let mut rng = Rng::new(11);
    let params: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(m.p_enc(), 0.1)).collect();
    let prop = RustPropagator::new(&m, 0.25, shared_params(params));
    let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let ct = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let cfg = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(3), bwd_iters: Some(2), fcf: true };

    let s1 = MgritSolver::new(&prop, cfg.clone());
    let (w1, _) = s1.forward(&z0, Some(3), None, false);
    let (l1, _) = s1.adjoint(&w1, &ct, Some(2), false);
    let g1 = s1.gradients(&w1, &l1);
    for workers in [2usize, 4] {
        let sn = MgritSolver::with_workers(&prop, cfg.clone(), workers);
        let (wn, _) = sn.forward(&z0, Some(3), None, false);
        for (a, b) in w1.iter().zip(&wn) {
            assert_eq!(a.data(), b.data(), "forward state, workers={}", workers);
        }
        let (ln, _) = sn.adjoint(&wn, &ct, Some(2), false);
        for (a, b) in l1.iter().zip(&ln) {
            assert_eq!(a.data(), b.data(), "adjoint state, workers={}", workers);
        }
        let gn = sn.gradients(&wn, &ln);
        assert_eq!(g1, gn, "gradients, workers={}", workers);
    }
}
