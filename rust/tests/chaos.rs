//! Chaos acceptance for the fault-injection harness (`--faults`) and the
//! self-healing policies it exercises. The contract, per fault class:
//! recovery is either **bitwise identical** to a run that never faulted
//! (step records, final parameters, served tokens) or a **documented
//! typed error/event** — never a poisoned Adam moment, a torn `.ltcp`
//! file, or a process abort.
//!
//! The fault registry is process-global (specs must cross pool-thread
//! boundaries), so every test here serializes on one lock and resets the
//! registry on entry and exit.

use std::sync::Mutex;

use layertime::checkpoint::{autosave_path, Checkpoint};
use layertime::config::{presets, MgritConfig, OptKind, RunConfig};
use layertime::coordinator::{AnomalyKind, Mgrit, Session, StepRecord, Task};
use layertime::fault;
use layertime::infer::InferSession;
use layertime::model::{Init, ParamStore};
use layertime::serve::{
    CompletedRequest, GenerateRequest, HotReload, RequestOutcome, RequestQueue, ServeError,
    ServeLoop,
};

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

/// Serialize on the shared lock and start from a clean (disarmed, empty
/// event log) registry.
fn chaos_guard() -> std::sync::MutexGuard<'static, ()> {
    let g = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::reset();
    g
}

fn has_event(point: &str, action: &str) -> bool {
    fault::events().iter().any(|e| e.point == point && e.action == action)
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("lt_chaos_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Exact-propagation training config (serial fwd/bwd, Adam, fixed
/// controller): the configuration under which a policy-1 rewind+replay is
/// pinned bitwise (no warm iterate to advance on the faulted attempt).
fn serial_rc(steps: usize) -> RunConfig {
    let mut rc = presets::by_name("mc").unwrap();
    presets::shrink_for_bench(&mut rc);
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: None, fcf: true };
    rc.train.steps = steps;
    rc.train.opt = OptKind::Adam;
    rc.train.adaptive = false;
    rc.train.eval_every = 1000;
    rc
}

/// MGRIT-both-directions config for the pooled-sweep fault classes.
fn mgrit_rc(steps: usize) -> RunConfig {
    let mut rc = serial_rc(steps);
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc
}

type RecBits = (usize, u32, u32, u32, bool, Option<u64>, Option<u64>);

fn bits(r: &StepRecord) -> RecBits {
    (
        r.step,
        r.loss.to_bits(),
        r.acc.to_bits(),
        r.lr.to_bits(),
        r.serial,
        r.rho_fwd.map(f64::to_bits),
        r.rho_bwd.map(f64::to_bits),
    )
}

fn params_bits(s: &Session) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = s
        .params
        .layers
        .read()
        .unwrap()
        .iter()
        .map(|l| l.iter().map(|x| x.to_bits()).collect())
        .collect();
    for g in [&s.params.w_emb, &s.params.w_pos, &s.params.w_out, &s.params.w_cls] {
        out.push(g.iter().map(|x| x.to_bits()).collect());
    }
    out
}

fn run_steps(rc: &RunConfig, workers: usize, n: usize) -> (Session, Vec<RecBits>) {
    let mut s =
        Session::builder().config(rc.clone()).task(Task::Tag).workers(workers).build().unwrap();
    let recs = (0..n).map(|_| bits(&s.train_step())).collect();
    (s, recs)
}

// --- policy 1: non-finite guard ----------------------------------------

#[test]
fn nan_gradient_step_is_skipped_and_replayed_bitwise() {
    let _g = chaos_guard();
    let rc = serial_rc(6);
    let (clean, clean_recs) = run_steps(&rc, 1, 6);

    fault::arm("train.nan_grad@step=2").unwrap();
    let (hurt, hurt_recs) = run_steps(&rc, 1, 6);

    assert_eq!(fault::fired("train.nan_grad"), 1);
    assert_eq!(clean_recs, hurt_recs, "the replayed run must be bitwise clean");
    assert_eq!(params_bits(&clean), params_bits(&hurt), "final parameters must match bitwise");
    assert!(hurt.moments_finite(), "Adam moments must never see the NaN");
    let an = hurt.anomalies();
    assert_eq!(an.len(), 1, "one typed anomaly for the one injected fault");
    assert!(matches!(an[0].kind, AnomalyKind::NonFiniteGrad));
    assert_eq!(an[0].step, 2);
    assert!(has_event("train.step_anomaly", "skipped_step"));
    fault::reset();
}

#[test]
fn kernel_nan_is_caught_before_the_optimizer_and_replayed_bitwise() {
    let _g = chaos_guard();
    let rc = serial_rc(5);
    let (clean, clean_recs) = run_steps(&rc, 1, 5);

    // poison the very first Φ forward evaluation: the NaN propagates
    // through loss and/or gradients and must be caught by the same guard
    fault::arm("kernel.phi_nan@step=1").unwrap();
    let (hurt, hurt_recs) = run_steps(&rc, 1, 5);

    assert_eq!(fault::fired("kernel.phi_nan"), 1);
    assert_eq!(clean_recs, hurt_recs, "the replayed run must be bitwise clean");
    assert_eq!(params_bits(&clean), params_bits(&hurt));
    assert!(hurt.moments_finite());
    assert_eq!(hurt.anomalies().len(), 1);
    assert_eq!(hurt.anomalies()[0].step, 1);
    fault::reset();
}

// --- policy 3: pooled-sweep panic recovery -----------------------------

#[test]
fn single_sweep_panic_retries_on_a_rebuilt_pool_bitwise() {
    let _g = chaos_guard();
    let rc = mgrit_rc(4);
    let (clean, clean_recs) = run_steps(&rc, 2, 4);

    fault::arm("pool.sweep_panic@step=3").unwrap();
    let (hurt, hurt_recs) = run_steps(&rc, 2, 4);

    assert_eq!(fault::fired("pool.sweep_panic"), 1);
    assert_eq!(clean_recs, hurt_recs, "the retried sweep must be bitwise clean");
    assert_eq!(params_bits(&clean), params_bits(&hurt));
    assert!(has_event("pool.sweep", "sweep_retry"));
    assert!(!has_event("pool.sweep", "sweep_serial_fallback"), "one panic needs no fallback");
    assert!(hurt.anomalies().is_empty(), "a recovered sweep is not a training anomaly");
    fault::reset();
}

#[test]
fn double_sweep_panic_falls_back_in_thread_bitwise() {
    let _g = chaos_guard();
    let rc = mgrit_rc(4);
    let (clean, clean_recs) = run_steps(&rc, 2, 4);

    // the first pooled sweep panics, its retry panics again (count=2), and
    // the in-thread V-cycle fallback — no pooled sweeps, so no more hits —
    // finishes the solve bitwise identically
    fault::arm("pool.sweep_panic@count=2").unwrap();
    let (hurt, hurt_recs) = run_steps(&rc, 2, 4);

    assert_eq!(fault::fired("pool.sweep_panic"), 2);
    assert_eq!(clean_recs, hurt_recs, "the in-thread fallback must be bitwise clean");
    assert_eq!(params_bits(&clean), params_bits(&hurt));
    assert!(has_event("pool.sweep", "sweep_retry"));
    assert!(has_event("pool.sweep", "sweep_serial_fallback"));
    fault::reset();
}

// --- policy 2: divergence watchdog auto-rollback ------------------------

#[test]
fn divergence_rollback_restores_the_autosave_and_replays_bitwise() {
    let _g = chaos_guard();
    let dir = tmp_dir("rollback");
    let base = dir.join("model.ltcp").to_str().unwrap().to_string();
    let mut rc = mgrit_rc(8);
    rc.train.adaptive = true; // the watchdog only arms on adaptive runs
    rc.train.probe_every = 100; // but keep the controller from switching

    let mut clean =
        Session::builder().config(rc.clone()).task(Task::Tag).workers(1).build().unwrap();
    let clean_report = clean.train().unwrap();

    let mut hurt =
        Session::builder().config(rc).task(Task::Tag).workers(1).build().unwrap();
    hurt.set_autosave(&base, 2, 0);
    // a finite 1e6 loss at step 5 trips the watchdog; the newest autosave
    // (step 4 — byte-identical to the clean run's state there, nothing
    // fired earlier) is restored in place and steps 5.. replay cleanly
    fault::arm("train.loss_spike@step=5").unwrap();
    let hurt_report = hurt.train().unwrap();

    assert_eq!(fault::fired("train.loss_spike"), 1);
    assert_eq!(hurt.rollback_count(), 1);
    let a: Vec<RecBits> = clean_report.curve.iter().map(bits).collect();
    let b: Vec<RecBits> = hurt_report.curve.iter().map(bits).collect();
    assert_eq!(a, b, "the rolled-back run's curve must be bitwise clean");
    assert_eq!(params_bits(&clean), params_bits(&hurt));
    assert_eq!(hurt_report.anomalies.len(), 1);
    assert!(matches!(hurt_report.anomalies[0].kind, AnomalyKind::Divergence));
    assert!(has_event("train.watchdog", "rollback"));
    let _ = std::fs::remove_dir_all(&dir);
    fault::reset();
}

// --- checkpoint fault classes -------------------------------------------

#[test]
fn partial_autosave_write_leaves_no_torn_checkpoint_and_training_continues() {
    let _g = chaos_guard();
    let dir = tmp_dir("autosave");
    let base = dir.join("model.ltcp").to_str().unwrap().to_string();
    let mut s = Session::builder().config(serial_rc(6)).task(Task::Tag).build().unwrap();
    s.set_autosave(&base, 2, 0);

    // the first autosave (step 2) crashes mid-write: half the bytes reach
    // the .tmp file and the rename never happens
    fault::arm("checkpoint.partial_write").unwrap();
    let report = s.train().unwrap();

    assert_eq!(fault::fired("checkpoint.partial_write"), 1);
    assert_eq!(report.curve.len(), 6, "a failed snapshot must not kill a healthy run");
    assert!(has_event("checkpoint.autosave", "autosave_failed"));
    assert!(
        !std::path::Path::new(&autosave_path(&base, 2)).exists(),
        "the torn write must not produce a .ltcp file"
    );
    let mut ltcp = 0;
    for e in std::fs::read_dir(&dir).unwrap() {
        let p = e.unwrap().path();
        if p.extension().and_then(|x| x.to_str()) == Some("ltcp") {
            ltcp += 1;
            Checkpoint::read(p.to_str().unwrap())
                .expect("every surviving .ltcp must read back clean");
        }
    }
    assert_eq!(ltcp, 2, "the step-4 and step-6 autosaves still landed");
    let _ = std::fs::remove_dir_all(&dir);
    fault::reset();
}

#[test]
fn corrupt_hot_reload_candidate_is_quarantined_with_a_typed_event() {
    let _g = chaos_guard();
    let dir = tmp_dir("reload");
    let mut s = Session::builder().config(serial_rc(2)).task(Task::Tag).build().unwrap();
    s.train_step();
    let good = dir.join("model.step00000001.ltcp");
    s.save(good.to_str().unwrap()).unwrap();
    // a lexicographically/mtime newer file that is torn garbage
    std::fs::write(dir.join("model.step00000002.ltcp"), b"torn garbage").unwrap();

    let mut hr = HotReload::new(dir.to_str().unwrap());
    let (path, _ck) = hr.poll().expect("the watcher must fall back to the older valid file");
    assert!(path.to_string_lossy().ends_with("model.step00000001.ltcp"));
    assert_eq!(hr.bad_files(), 1);
    assert!(has_event("serve.reload", "reload_quarantined"));
    let _ = std::fs::remove_dir_all(&dir);
    fault::reset();
}

// --- serve fault classes -------------------------------------------------

#[test]
fn queue_overflow_and_close_are_typed_backpressure_not_fatal() {
    let _g = chaos_guard();
    let q = RequestQueue::new(2, 4);
    q.submit(GenerateRequest::greedy(0, vec![1])).unwrap();
    q.submit(GenerateRequest::greedy(1, vec![1])).unwrap();
    assert_eq!(
        q.submit(GenerateRequest::greedy(2, vec![1])).unwrap_err(),
        ServeError::QueueFull { capacity: 2 }
    );
    q.close();
    assert_eq!(q.submit(GenerateRequest::greedy(3, vec![1])).unwrap_err(), ServeError::Closed);
    // graceful drain: work accepted before close is still served
    assert!(q.pop().is_some() && q.pop().is_some());
    assert!(q.pop().is_none());
    assert_eq!(q.stats().rejected, 1);
}

fn lm_session() -> InferSession {
    let mut rc = presets::by_name("gpt").unwrap();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_dec_layers = 6;
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    rc.model.batch = 2;
    let params = ParamStore::init(&rc.model, Init::Default, 5);
    InferSession::from_parts(rc, params, Box::new(Mgrit)).unwrap()
}

#[test]
fn injected_deadline_times_out_one_request_without_touching_its_neighbor() {
    let _g = chaos_guard();
    let victim = GenerateRequest {
        max_new: 5,
        deadline_ms: 60_000, // never expires for real — only by injection
        ..GenerateRequest::greedy(1, vec![1, 2])
    };
    let bystander = GenerateRequest { max_new: 5, ..GenerateRequest::greedy(2, vec![3, 4]) };
    let run_pair = |victim: &GenerateRequest, bystander: &GenerateRequest| {
        let mut srv = ServeLoop::new(lm_session(), 4).unwrap();
        srv.submit(victim.clone()).unwrap();
        srv.submit(bystander.clone()).unwrap();
        let mut guard = 0;
        while srv.active() > 0 || srv.queue().depth() > 0 {
            srv.step().unwrap();
            guard += 1;
            assert!(guard < 200, "serve loop failed to drain");
        }
        let mut done: Vec<CompletedRequest> = srv.take_completed();
        done.sort_by_key(|d| d.id);
        (done, srv.metrics.timeouts)
    };

    let (clean, clean_timeouts) = run_pair(&victim, &bystander);
    assert_eq!(clean_timeouts, 0);
    assert!(clean.iter().all(|c| c.outcome == RequestOutcome::Done));

    // the deadline sweep's first armed hit (step 2, after one token
    // landed) retires the victim with a typed Timeout
    fault::arm("serve.deadline").unwrap();
    let (hurt, hurt_timeouts) = run_pair(&victim, &bystander);
    assert_eq!(hurt_timeouts, 1);
    assert_eq!(hurt[0].outcome, RequestOutcome::Timeout);
    assert_eq!(hurt[0].generated, 1, "the one token decoded before expiry comes back");
    assert_eq!(
        hurt[0].tokens[..],
        clean[0].tokens[..hurt[0].tokens.len()],
        "a timed-out request returns a prefix of its clean tokens"
    );
    assert_eq!(hurt[1].outcome, RequestOutcome::Done);
    assert_eq!(hurt[1].tokens, clean[1].tokens, "the neighbour's tokens must not move");
    assert!(has_event("serve.deadline", "timeout"));
    fault::reset();
}
