//! Scheduler parity pins for the continuous-batching serve subsystem.
//!
//! The contract under test: a request's tokens are a function of
//! (checkpoint, request) only — independent of when it joined the batch,
//! which slot it landed in, how many neighbours decoded beside it, and
//! when they retired. Greedy parity is bitwise; top-k parity holds because
//! every slot samples from its own `Rng::new(request.seed)` stream.
//! Plus: backpressure via the bounded queue, hot-reload swapping weights
//! only between decode steps (corrupt files skipped), and `--save-every`
//! autosave + retention feeding the watcher.

use layertime::checkpoint::{autosave_path, Checkpoint, ControllerState};
use layertime::config::{presets, MgritConfig, RunConfig};
use layertime::coordinator::{Mgrit, Session, Task};
use layertime::infer::{DecodeOptions, InferSession};
use layertime::model::{Init, ParamStore};
use layertime::serve::{
    CompletedRequest, GenerateRequest, HotReload, ServeError, ServeLoop,
};

fn tiny_rc(batch: usize) -> RunConfig {
    let mut rc = presets::by_name("gpt").expect("gpt preset");
    presets::shrink_for_bench(&mut rc);
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = batch;
    rc.model.n_classes = 4;
    rc.model.n_dec_layers = 6;
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc
}

fn session(batch: usize, params_seed: u64) -> InferSession {
    let rc = tiny_rc(batch);
    let params = ParamStore::init(&rc.model, Init::Default, params_seed);
    InferSession::from_parts(rc, params, Box::new(Mgrit)).expect("infer session")
}

fn serve_to_completion(srv: &mut ServeLoop) -> Vec<CompletedRequest> {
    let mut guard = 0;
    while srv.active() > 0 || srv.queue().depth() > 0 {
        srv.step().expect("serve step");
        guard += 1;
        assert!(guard < 1000, "serve loop failed to drain");
    }
    srv.take_completed()
}

/// Run one request alone through a fresh serve loop (the solo reference).
fn solo_tokens(batch: usize, params_seed: u64, req: &GenerateRequest) -> Vec<i32> {
    let mut srv = ServeLoop::new(session(batch, params_seed), 4).unwrap();
    srv.submit(req.clone()).unwrap();
    let mut done = serve_to_completion(&mut srv);
    assert_eq!(done.len(), 1);
    done.pop().unwrap().tokens
}

#[test]
fn join_mid_flight_and_early_retirement_match_solo_runs() {
    let (b, seed) = (2, 5);
    // A retires early (3 tokens); C joins mid-flight and fills the window
    let a = GenerateRequest { max_new: 3, ..GenerateRequest::greedy(0, vec![1, 2, 3]) };
    let c = GenerateRequest {
        top_k: 4,
        temperature: 0.9,
        seed: 11,
        ..GenerateRequest::greedy(1, vec![4])
    };
    let solo_a = solo_tokens(b, seed, &a);
    let solo_c = solo_tokens(b, seed, &c);

    let mut srv = ServeLoop::new(session(b, seed), 4).unwrap();
    srv.submit(a).unwrap();
    srv.step().unwrap();
    srv.step().unwrap();
    // C joins while A is mid-flight; A retires one step later while C
    // keeps decoding against A's stale board row
    srv.submit(c).unwrap();
    let mut done = serve_to_completion(&mut srv);
    done.sort_by_key(|d| d.id);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].tokens, solo_a, "the running request must not feel the joiner");
    assert_eq!(done[1].tokens, solo_c, "a mid-flight joiner must decode exactly like solo");
    assert_eq!(srv.metrics.peak_occupancy, 2);
    assert_eq!(done[0].generated, 3);
    assert_eq!(done[1].generated, 7);
}

#[test]
fn same_request_identical_at_occupancy_1_vs_8() {
    let (b, seed) = (8, 9);
    let target = GenerateRequest {
        top_k: 4,
        temperature: 0.8,
        seed: 77,
        ..GenerateRequest::greedy(100, vec![3, 1])
    };
    let solo = solo_tokens(b, seed, &target);

    let mut srv = ServeLoop::new(session(b, seed), 16).unwrap();
    // three different requests ahead of the target (it lands in slot 3,
    // not slot 0) and four more behind it — full occupancy, every
    // neighbour sampling from its own stream
    for i in 0..8u64 {
        if i == 3 {
            srv.submit(target.clone()).unwrap();
            continue;
        }
        let other = GenerateRequest {
            top_k: 3,
            temperature: 1.1,
            seed: 1000 + i,
            ..GenerateRequest::greedy(i, vec![(i % 5) as i32 + 1, (i % 3) as i32])
        };
        srv.submit(other).unwrap();
    }
    let done = serve_to_completion(&mut srv);
    assert_eq!(srv.metrics.peak_occupancy, 8);
    let got = &done.iter().find(|d| d.id == 100).unwrap().tokens;
    assert_eq!(got, &solo, "top-k tokens must be occupancy- and slot-independent");
}

#[test]
fn serve_rows_match_generate_into_bitwise() {
    let (b, seed) = (2, 5);
    let (s, plen) = (8, 3);
    let prompts: Vec<i32> = (0..b * plen).map(|i| (i % 7) as i32).collect();
    let mut inf = session(b, seed);
    let full = inf.generate(&prompts, plen, &DecodeOptions::default()).unwrap();

    // both requests admitted at the first step = the same cold start and
    // warm chaining generate_into performs — rows must match bitwise
    let mut srv = ServeLoop::new(session(b, seed), 4).unwrap();
    for bi in 0..b {
        srv.submit(GenerateRequest::greedy(
            bi as u64,
            prompts[bi * plen..(bi + 1) * plen].to_vec(),
        ))
        .unwrap();
    }
    let mut done = serve_to_completion(&mut srv);
    done.sort_by_key(|d| d.id);
    for bi in 0..b {
        assert_eq!(
            done[bi].tokens,
            full[bi * s..(bi + 1) * s].to_vec(),
            "serve slot {} diverged from the generate_into row",
            bi
        );
    }
}

#[test]
fn backpressure_rejects_past_capacity_through_the_serve_front() {
    let srv = ServeLoop::new(session(2, 1), 2).unwrap();
    srv.submit(GenerateRequest::greedy(0, vec![1])).unwrap();
    srv.submit(GenerateRequest::greedy(1, vec![1])).unwrap();
    assert_eq!(
        srv.submit(GenerateRequest::greedy(2, vec![1])),
        Err(ServeError::QueueFull { capacity: 2 })
    );
    // the window must leave room to generate: seq 8 admits prompts ≤ 7
    assert!(matches!(
        srv.submit(GenerateRequest::greedy(3, vec![0; 8])),
        Err(ServeError::Invalid(_))
    ));
    let q = srv.queue();
    assert_eq!(q.stats().rejected, 1);
    q.close();
    assert_eq!(srv.submit(GenerateRequest::greedy(4, vec![1])), Err(ServeError::Closed));
}

/// A hand-built checkpoint image over freshly initialized parameters
/// (optimizer/controller state is irrelevant to serving).
fn checkpoint_for(rc: &RunConfig, params_seed: u64, step: usize) -> Checkpoint {
    let ps = ParamStore::init(&rc.model, Init::Default, params_seed);
    let sizes = ps.group_sizes();
    let layers = ps.layers.read().unwrap().clone();
    Checkpoint {
        rc: rc.clone(),
        step,
        initial_loss: None,
        switched_at: None,
        warm_start: true,
        rng_state: 1,
        rng_spare: None,
        controller: ControllerState {
            probe_every: 50,
            rho_switch: 1.0,
            rho_grow: 0.9,
            max_iters: 8,
            step,
            switched: false,
            history_cap: 512,
            history: vec![],
        },
        opt_t: step as u64,
        opt_m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
        opt_v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
        layers,
        w_emb: ps.w_emb.clone(),
        w_pos: ps.w_pos.clone(),
        w_out: ps.w_out.clone(),
        w_cls: ps.w_cls.clone(),
        warm: None,
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("layertime_serve_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn hot_reload_swaps_between_steps_and_skips_corrupt_files() {
    let rc = tiny_rc(2);
    let dir = tmp_dir("reload");
    let ck1 = checkpoint_for(&rc, 5, 1);
    let ck2 = checkpoint_for(&rc, 6, 2);
    ck1.write(dir.join("m.step00000001.ltcp").to_str().unwrap()).unwrap();

    let req = GenerateRequest::greedy(0, vec![1, 2, 3]);
    let plen = 3;

    // reference: the request served entirely under ck1 (no watcher)
    let solo_ck1 = {
        let inf = InferSession::from_checkpoint_parts(ck1.clone(), 1).unwrap();
        let mut srv = ServeLoop::new(inf, 4).unwrap();
        srv.submit(req.clone()).unwrap();
        serve_to_completion(&mut srv).pop().unwrap().tokens
    };

    // watched serve: start from the newest valid file, decode two steps,
    // then drop a newer valid checkpoint AND an even newer corrupt file
    let mut hr = HotReload::new(dir.to_str().unwrap());
    let (_path, ck) = hr.poll().expect("startup checkpoint");
    let inf = InferSession::from_checkpoint_parts(ck, 1).unwrap();
    let mut srv = ServeLoop::new(inf, 4).unwrap();
    srv.set_watch(hr, 1); // poll at every step boundary
    srv.submit(req).unwrap();
    srv.step().unwrap();
    srv.step().unwrap();
    ck2.write(dir.join("m.step00000002.ltcp").to_str().unwrap()).unwrap();
    std::fs::write(dir.join("m.step00000003.ltcp"), b"definitely not a checkpoint").unwrap();
    let done = serve_to_completion(&mut srv);
    let tokens = &done[0].tokens;

    assert_eq!(srv.metrics.reloads, 1, "swapped once; the corrupt newer file was skipped");
    assert_eq!(
        &tokens[..plen + 2],
        &solo_ck1[..plen + 2],
        "tokens emitted before the swap came from the old snapshot"
    );
    // boundary semantics: post-swap decoding must equal a fresh ck2 serve
    // whose prompt is everything emitted so far (same board, cold warm
    // state) — i.e. the swap happened exactly between decode steps
    let cont = {
        let inf = InferSession::from_checkpoint_parts(ck2, 1).unwrap();
        let mut srv = ServeLoop::new(inf, 4).unwrap();
        srv.submit(GenerateRequest::greedy(9, tokens[..plen + 2].to_vec())).unwrap();
        serve_to_completion(&mut srv).pop().unwrap().tokens
    };
    assert_eq!(tokens, &cont, "post-swap tokens must come from the new snapshot");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mismatched_checkpoint_is_quarantined_not_fatal() {
    let rc = tiny_rc(2);
    let dir = tmp_dir("mismatch");
    checkpoint_for(&rc, 5, 1).write(dir.join("m.step00000001.ltcp").to_str().unwrap()).unwrap();
    let inf = InferSession::from_checkpoint_parts(checkpoint_for(&rc, 5, 1), 1).unwrap();
    let mut srv = ServeLoop::new(inf, 4).unwrap();
    let mut hr = HotReload::new(dir.to_str().unwrap());
    hr.poll().expect("startup checkpoint");
    srv.set_watch(hr, 1);
    // a newer checkpoint with a different model shape reads fine but
    // cannot be served — it must be skipped, not crash the loop
    let other_rc = tiny_rc(4);
    checkpoint_for(&other_rc, 6, 2)
        .write(dir.join("m.step00000002.ltcp").to_str().unwrap())
        .unwrap();
    srv.submit(GenerateRequest::greedy(0, vec![1])).unwrap();
    let done = serve_to_completion(&mut srv);
    assert_eq!(done.len(), 1);
    assert_eq!(srv.metrics.reloads, 0, "shape-mismatched checkpoint must not swap in");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn autosave_retention_feeds_the_watcher() {
    let mut rc = tiny_rc(2);
    rc.train.steps = 4;
    rc.train.eval_every = 100;
    rc.train.adaptive = false;
    rc.train.probe_every = 0;
    rc.train.warmup = 0;
    let dir = tmp_dir("autosave");
    let base = dir.join("gpt.ltcp");
    let mut run = Session::builder()
        .config(rc)
        .task(Task::Lm)
        .backend(Box::new(Mgrit))
        .build()
        .expect("training session");
    run.set_autosave(base.to_str().unwrap(), 1, 2);
    run.train().expect("train");

    let mut files: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    files.sort();
    assert_eq!(
        files,
        vec!["gpt.step00000003.ltcp", "gpt.step00000004.ltcp"],
        "every-step autosave with keep=2 retains exactly the two newest"
    );
    // expected filenames really are the autosave_path naming
    assert!(autosave_path(base.to_str().unwrap(), 4).ends_with("gpt.step00000004.ltcp"));

    // a cold watcher picks the newest autosave and it serves end to end
    let mut hr = HotReload::new(dir.to_str().unwrap());
    let (path, ck) = hr.poll().expect("newest autosave");
    assert!(path.to_string_lossy().ends_with("gpt.step00000004.ltcp"));
    assert_eq!(ck.step, 4);
    let inf = InferSession::from_checkpoint_parts(ck, 1).unwrap();
    let mut srv = ServeLoop::new(inf, 4).unwrap();
    srv.submit(GenerateRequest::greedy(0, vec![1])).unwrap();
    let done = serve_to_completion(&mut srv);
    assert_eq!(done.len(), 1);
    assert!(done[0].generated > 0);
    let _ = std::fs::remove_dir_all(&dir);
}
