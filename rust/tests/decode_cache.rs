//! Parity and cost pins for the incremental KV-cached decode path.
//!
//! The contract under test: with incremental decode on (the default), the
//! prompt costs **one exact serial forward** (which also fills the cache)
//! and every further token costs **one cached Φ sweep** — O(1) per layer,
//! independent of the board length — and the emitted tokens are **bitwise
//! identical** to the historical full-forward-per-token loop run serially.
//! That equivalence is not approximate: the row-sliced matmul, masked
//! softmax, layer-norm and GELU kernels are all row/prefix-exact, so a
//! single-row cached step reproduces the full-board row bit for bit.
//! Covered here end to end: `generate` (greedy + top-k, batch 1 and 8),
//! encoder-decoder `translate`, the serve scheduler (join-mid-flight and
//! early retirement against the full-forward loop token for token), and
//! the Φ-evaluation counters that pin the O(1) cost claim itself.

use layertime::config::{presets, Arch, MgritConfig, RunConfig};
use layertime::coordinator::Mgrit;
use layertime::infer::{DecodeOptions, InferSession};
use layertime::model::{Init, ParamStore};
use layertime::serve::{CompletedRequest, GenerateRequest, ServeLoop};

fn tiny_rc(preset: &str, batch: usize) -> RunConfig {
    let mut rc = presets::by_name(preset).expect("preset");
    presets::shrink_for_bench(&mut rc);
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = batch;
    rc.model.n_classes = 4;
    if rc.model.arch == Arch::EncDec {
        rc.model.n_enc_layers = 2;
        rc.model.n_dec_layers = 2;
    } else {
        rc.model.n_dec_layers = 6;
    }
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc
}

fn session(preset: &str, batch: usize, params_seed: u64) -> InferSession {
    let rc = tiny_rc(preset, batch);
    let params = ParamStore::init(&rc.model, Init::Default, params_seed);
    InferSession::from_parts(rc, params, Box::new(Mgrit)).expect("infer session")
}

/// Sampling configs exercised by every parity test: greedy argmax and
/// seeded top-k (both deterministic, so "equal" means bitwise).
fn parity_opts() -> [DecodeOptions; 2] {
    [
        DecodeOptions::default(),
        DecodeOptions { top_k: 4, temperature: 0.8, seed: 9, max_new: 0 },
    ]
}

#[test]
fn lm_generate_cached_matches_full_forward_bitwise() {
    for batch in [1usize, 8] {
        let mut inf = session("gpt", batch, 5);
        // the cached path's prefill always runs serially, so the serial
        // full-forward loop is the like-for-like reference
        inf.set_fwd_iters(None);
        let (b, seq) = (inf.rc.model.batch, inf.rc.model.seq);
        let plen = seq / 2;
        let prompts: Vec<i32> = (0..b * plen).map(|i| (i % 7) as i32).collect();
        for opts in parity_opts() {
            assert!(inf.incremental(), "incremental decode is the default");
            let cached = inf.generate(&prompts, plen, &opts).unwrap();
            inf.set_incremental(false);
            let full = inf.generate(&prompts, plen, &opts).unwrap();
            inf.set_incremental(true);
            assert_eq!(
                cached, full,
                "cached decode diverged from the full-forward loop (batch {}, top_k {})",
                batch, opts.top_k
            );
        }
    }
}

#[test]
fn translate_cached_matches_full_forward_bitwise() {
    let mut inf = session("mt", 2, 11);
    inf.set_fwd_iters(None);
    let (b, seq) = (inf.rc.model.batch, inf.rc.model.seq);
    let src: Vec<i32> = (0..b * seq).map(|i| (i % 7) as i32).collect();
    for opts in parity_opts() {
        let cached = inf.translate(&src, &opts).unwrap();
        inf.set_incremental(false);
        let full = inf.translate(&src, &opts).unwrap();
        inf.set_incremental(true);
        assert_eq!(
            cached, full,
            "cached translate diverged from the full-forward loop (top_k {})",
            opts.top_k
        );
    }
}

fn serve_to_completion(srv: &mut ServeLoop) -> Vec<CompletedRequest> {
    let mut guard = 0;
    while srv.active() > 0 || srv.queue().depth() > 0 {
        srv.step().expect("serve step");
        guard += 1;
        assert!(guard < 1000, "serve loop failed to drain");
    }
    srv.take_completed()
}

/// Drive the same request pair — one early-retiring greedy request and a
/// top-k request that optionally joins mid-flight — through a serve loop
/// in the given decode mode, returning `(id, tokens)` sorted by id.
fn serve_tokens(incremental: bool, join_mid_flight: bool) -> Vec<(u64, Vec<i32>)> {
    let mut inf = session("gpt", 2, 5);
    inf.set_fwd_iters(None); // serial reference mode (see the generate pin)
    inf.set_incremental(incremental);
    let a = GenerateRequest { max_new: 3, ..GenerateRequest::greedy(0, vec![1, 2, 3]) };
    let c = GenerateRequest {
        top_k: 4,
        temperature: 0.9,
        seed: 11,
        ..GenerateRequest::greedy(1, vec![4])
    };
    let mut srv = ServeLoop::new(inf, 4).unwrap();
    srv.submit(a).unwrap();
    if join_mid_flight {
        // C joins while A is mid-flight; A retires 3 tokens in and its
        // freed slot keeps idling while C fills the window
        srv.step().unwrap();
        srv.step().unwrap();
    }
    srv.submit(c).unwrap();
    let mut done = serve_to_completion(&mut srv);
    done.sort_by_key(|d| d.id);
    done.into_iter().map(|d| (d.id, d.tokens)).collect()
}

#[test]
fn serve_cached_matches_full_forward_token_for_token() {
    // both admission patterns: everyone at step 1, and a mid-flight join
    // (which makes the joiner's first step a prefill against warm rows)
    for join_mid_flight in [false, true] {
        let cached = serve_tokens(true, join_mid_flight);
        let full = serve_tokens(false, join_mid_flight);
        assert_eq!(cached.len(), 2);
        assert_eq!(
            cached, full,
            "serve tokens diverged between decode modes (join_mid_flight {})",
            join_mid_flight
        );
    }
}

#[test]
fn cached_decode_is_o1_per_token_and_builds_no_core() {
    let mut inf = session("gpt", 2, 7);
    let n_layers = inf.rc.model.total_layers() as u64;
    let b = inf.rc.model.batch;
    let plen = 3;
    let prompts: Vec<i32> = (0..b * plen).map(|i| (i % 5) as i32).collect();
    // warm call: builds the cache slabs, sizes the scratch
    inf.generate(&prompts, plen, &DecodeOptions::default()).unwrap();
    let base_builds = inf.core_builds();
    for max_new in [2usize, 5] {
        let opts = DecodeOptions { max_new, ..DecodeOptions::default() };
        let fwd0 = inf.phi_counters().fwd();
        let cached0 = inf.phi_counters().cached();
        inf.generate(&prompts, plen, &opts).unwrap();
        assert_eq!(
            inf.phi_counters().fwd() - fwd0,
            n_layers,
            "prompt ingest is exactly one serial forward, independent of max_new"
        );
        assert_eq!(
            inf.phi_counters().cached() - cached0,
            (max_new as u64 - 1) * n_layers,
            "each token after the first is exactly one O(1) cached Φ sweep"
        );
    }
    // the cached path never touches the MGRIT hierarchy (note the session
    // config asks for MGRIT: incremental prefills still force serial)
    assert_eq!(inf.core_builds(), base_builds, "cached decode must not build a core");
    // with incremental off the cached counter stays flat — the full loop
    // really is full forwards
    inf.set_incremental(false);
    let cached0 = inf.phi_counters().cached();
    let fwd0 = inf.phi_counters().fwd();
    inf.generate(&prompts, plen, &DecodeOptions { max_new: 2, ..DecodeOptions::default() })
        .unwrap();
    assert_eq!(inf.phi_counters().cached(), cached0);
    assert!(
        inf.phi_counters().fwd() - fwd0 >= 2 * n_layers,
        "the full-forward loop pays a whole forward per generated token"
    );
}
