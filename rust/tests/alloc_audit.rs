//! Zero-allocation audit of the steady-state Φ hot path.
//!
//! Installs a counting global allocator (this file is its own test binary,
//! and it contains exactly one #[test] so no concurrent test can perturb
//! the counter) and pins the acceptance criterion: once the scratch pool
//! and parameter views are warm, `RustPropagator::step_into` performs
//! **zero heap allocations** per step, for both the flat encoder state and
//! the stacked encoder-decoder state.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use layertime::config::{Arch, ModelConfig};
use layertime::ode::{shared_params, Propagator, RustPropagator};
use layertime::tensor::Tensor;
use layertime::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn tiny_model(arch: Arch) -> ModelConfig {
    ModelConfig {
        arch,
        vocab: 8,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        seq: 4,
        batch: 2,
        n_classes: 2,
        n_enc_layers: if arch == Arch::EncDec { 2 } else { 4 },
        n_dec_layers: if arch == Arch::EncDec { 2 } else { 0 },
        buffer_open: 0,
        buffer_close: 0,
    }
}

fn audit_arch(arch: Arch) {
    let model = tiny_model(arch);
    let mut rng = Rng::new(11);
    let mut layers = Vec::new();
    for l in 0..model.total_layers() {
        let len = if model.arch == Arch::EncDec && l >= model.n_enc_layers {
            model.p_dec()
        } else {
            model.p_enc()
        };
        layers.push(rng.normal_vec(len, 0.1));
    }
    let prop = RustPropagator::new(&model, 1.0, shared_params(layers));
    let z = Tensor::randn(&mut rng, &prop.state_shape(), 0.8);
    let mut out = Tensor::zeros(&prop.state_shape());

    // warm up: the scratch pool allocates its buffers on the first few
    // applications (covering every layer phase) and the pooled buffers
    // then cycle through their slots until every capacity suffices
    for _ in 0..10 {
        for layer in 0..prop.n_steps() {
            prop.step_into(layer, 1.0, &z, &mut out);
        }
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        for layer in 0..prop.n_steps() {
            prop.step_into(layer, 1.0, &z, &mut out);
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{:?}: step_into allocated {} times over {} steady-state steps",
        arch,
        after - before,
        5 * prop.n_steps()
    );
}

/// Single test (see module docs): steady-state step_into is allocation-free.
#[test]
fn step_into_steady_state_is_allocation_free() {
    audit_arch(Arch::Encoder);
    audit_arch(Arch::EncDec);
}
