//! Zero-allocation audit of the steady-state training hot path.
//!
//! Installs a counting global allocator (this file is its own test binary,
//! and it contains exactly one #[test] so no concurrent test can perturb
//! the counter) and pins four acceptance criteria:
//!
//! 1. once the scratch pool and parameter views are warm,
//!    `RustPropagator::step_into` performs **zero heap allocations** per
//!    step, for both the flat encoder state and the stacked
//!    encoder-decoder state;
//! 2. the persistent solve context performs **zero heap allocations** for
//!    a complete steady-state forward-solve + adjoint-solve + gradients
//!    round on the single-threaded `Mgrit` backend (cached hierarchies,
//!    workspace handoff, warm-start refresh);
//! 3. the same round on the `ThreadedMgrit` backend (workers ∈ {2, 4}) is
//!    **also zero-allocation** after warmup: the in-place slab executors
//!    relax on the shared level storage, `WorkerPool::run_sweep`
//!    dispatches one borrowed closure (no boxing, no channels), halo
//!    messages recycle the endpoints' flat scratch (`comm::RETURN_BIT`
//!    protocol), and the per-worker boundary temps persist in the pool
//!    workspaces;
//! 4. a full `Session::train_step` at steady state allocates **exactly
//!    zero** times — the allowlist that used to cover data sampling, the
//!    loss head, and the clip ref-list is empty: `Objective::sample_into`
//!    refills the session's long-lived `TrainBatch`, `Objective::loss_into`
//!    writes into the workspace's cotangent buffer and accumulates head
//!    gradients directly, and `StepWorkspace::clip_global` walks the
//!    accumulators without a ref-list — and the **sharded data-parallel**
//!    step holds the same pin: concurrent replica lanes on the dp
//!    scheduler pool, per-replica contexts and batches refilled in place,
//!    flat gradient payloads on the fabric's recycled send scratch, and
//!    the ascending fold into replica 0's accumulators;
//! 5. the steady-state **batched decode loop** of an `InferSession`
//!    allocates exactly zero times, for both the greedy and the top-k
//!    sampling paths and in **both decode modes** — the incremental
//!    KV-cached path (serial prefill + O(1) cached Φ sweeps; cache slabs,
//!    row state, and position/token scratch all persist) and the
//!    historical full-forward-per-token path — the serving twin of pin 4;
//! 6. the continuous-batching **serve scheduler step** (`ServeLoop::step`:
//!    empty-queue admission poll, batched forward with per-row cursors,
//!    per-slot greedy + top-k sampling, metrics recording) also allocates
//!    exactly zero times once warm, again in both decode modes — the
//!    bounded queue, slot table, board, retirement list, decode cache, and
//!    capped metrics samples are all preallocated.
//!
//! Every audited path now crosses **disarmed `faultpoint!` sites**
//! ([`layertime::fault`]): the kernel layer (`kernel.phi_nan`), the pooled
//! sweeps (`pool.sweep_panic`), the train step (`train.nan_grad`,
//! `train.loss_spike`), and the serve scheduler (`serve.deadline`). The
//! audit runs with the registry disarmed — its entire cost is one relaxed
//! atomic load per site — so the zero-allocation pins above double as the
//! zero-cost-when-disarmed acceptance criterion of the fault harness.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use layertime::config::{presets, Arch, MgritConfig, ModelConfig};
use layertime::coordinator::{
    ForwardWorkspace, Mgrit, Session, SolveContext, StepWorkspace, Task, ThreadedMgrit,
};
use layertime::infer::{DecodeOptions, InferSession};
use layertime::model::{Init, ParamStore};
use layertime::ode::{shared_params, Propagator, RustPropagator};
use layertime::serve::{GenerateRequest, ServeLoop};
use layertime::tensor::Tensor;
use layertime::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn tiny_model(arch: Arch) -> ModelConfig {
    ModelConfig {
        arch,
        vocab: 8,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        seq: 4,
        batch: 2,
        n_classes: 2,
        n_enc_layers: if arch == Arch::EncDec { 2 } else { 4 },
        n_dec_layers: if arch == Arch::EncDec { 2 } else { 0 },
        buffer_open: 0,
        buffer_close: 0,
    }
}

fn audit_arch(arch: Arch) {
    let model = tiny_model(arch);
    let mut rng = Rng::new(11);
    let mut layers = Vec::new();
    for l in 0..model.total_layers() {
        let len = if model.arch == Arch::EncDec && l >= model.n_enc_layers {
            model.p_dec()
        } else {
            model.p_enc()
        };
        layers.push(rng.normal_vec(len, 0.1));
    }
    let prop = RustPropagator::new(&model, 1.0, shared_params(layers));
    let z = Tensor::randn(&mut rng, &prop.state_shape(), 0.8);
    let mut out = Tensor::zeros(&prop.state_shape());

    // warm up: the scratch pool allocates its buffers on the first few
    // applications (covering every layer phase) and the pooled buffers
    // then cycle through their slots until every capacity suffices
    for _ in 0..10 {
        for layer in 0..prop.n_steps() {
            prop.step_into(layer, 1.0, &z, &mut out);
        }
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        for layer in 0..prop.n_steps() {
            prop.step_into(layer, 1.0, &z, &mut out);
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{:?}: step_into allocated {} times over {} steady-state steps",
        arch,
        after - before,
        5 * prop.n_steps()
    );
}

/// The persistent-context pin: a steady-state forward + adjoint +
/// gradients round on cached cores allocates nothing at all. `workers = 1`
/// runs the single-threaded `Mgrit` backend; `workers > 1` runs
/// `ThreadedMgrit` with its persistent pool and the in-place slab
/// executors — the zero-copy acceptance criterion of the threaded path.
fn audit_solve_context(workers: usize) {
    let model = tiny_model(Arch::Encoder);
    let n = model.total_layers();
    let mut rng = Rng::new(12);
    let layers: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(model.p_enc(), 0.1)).collect();
    let theta_lens: Vec<usize> = layers.iter().map(|t| t.len()).collect();
    let prop = RustPropagator::new(&model, 1.0, shared_params(layers));
    let shape = prop.state_shape();
    let fwd_ws = ForwardWorkspace::new(n, &shape, &shape);
    let ws = StepWorkspace::new(n, &shape, &shape, &theta_lens, [0, 0, 0, 0]);
    let backend: Box<dyn layertime::coordinator::Backend> = if workers > 1 {
        Box::new(ThreadedMgrit::new(workers))
    } else {
        Box::new(Mgrit)
    };
    let mut ctx = SolveContext::new(backend, fwd_ws, ws);
    let cfg = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    let z = Tensor::randn(&mut rng, &shape, 0.8);
    let ct = Tensor::randn(&mut rng, &shape, 1.0);

    let mut round = |ctx: &mut SolveContext| {
        ctx.forward_mid(&prop, &cfg, 0, Some(1), true, false);
        ctx.ws.lams[n].copy_from(&ct);
        ctx.adjoint_mid(&prop, &cfg, 0, Some(1), false);
        ctx.gradients_mid(&prop, 0);
    };

    // warm up: builds both cores, the worker pool + workspaces + halo
    // scratch (threaded), the warm iterate, and the Φ scratch pool
    ctx.fwd.ws.states[0].copy_from(&z);
    for _ in 0..5 {
        round(&mut ctx);
    }
    assert_eq!(ctx.core_builds(), 2);

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        round(&mut ctx);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "solve context (workers={}) allocated {} times over 5 steady-state rounds",
        workers,
        after - before
    );
    assert_eq!(ctx.core_builds(), 2, "steady state must not rebuild cores");
}

/// The full-step pin: a steady-state `train_step` allocates literally
/// zero times (empty allowlist — see the module docs).
fn audit_train_step() {
    let mut rc = presets::by_name("mc").expect("mc preset");
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_enc_layers = 8;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.probe_every = 0;
    rc.train.adaptive = false;
    rc.train.warmup = 0;
    let mut s = Session::builder()
        .config(rc)
        .task(Task::Tag)
        .backend(Box::new(Mgrit))
        .build()
        .expect("session");

    // warm up: lazy core construction, warm iterate, batch buffer and
    // loss-head scratch sizing, Φ scratch pool growth
    for _ in 0..4 {
        s.train_step();
    }

    for step in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        s.train_step();
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            delta, 0,
            "train_step allocated {} times at steady state (step {}); the allowlist is empty",
            delta, step
        );
    }
}

/// The sharded-dp pin: a steady-state data-parallel `train_step` —
/// `dp_workers` concurrent replica lanes dispatched on the dp scheduler
/// pool, each lane solving its replica's micro-batch and shipping the flat
/// gradient payload to replica 0 over the fabric, folded in ascending
/// replica order — allocates exactly zero times. Warmup covers the lane
/// pool spawn, the fabric's send/recv scratch sizing, and every replica's
/// core + warm-iterate construction.
fn audit_train_step_dp(workers: usize, dp_workers: usize) {
    let mut rc = presets::by_name("mc").expect("mc preset");
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_enc_layers = 8;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.probe_every = 0;
    rc.train.adaptive = false;
    rc.train.warmup = 0;
    rc.dp_degree = 2;
    let mut s = Session::builder()
        .config(rc)
        .task(Task::Tag)
        .workers(workers)
        .dp_workers(dp_workers)
        .build()
        .expect("session");

    for _ in 0..4 {
        s.train_step();
    }

    for step in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        s.train_step();
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            delta, 0,
            "sharded-dp train_step (workers={}, dp_workers={}) allocated {} times at steady \
             state (step {})",
            workers, dp_workers, delta, step
        );
    }
}

/// The decode pin: the steady-state batched autoregressive decode loop of
/// an `InferSession` allocates exactly zero times, greedy and top-k both.
/// `incremental = true` audits the KV-cached path (serial prefill + O(1)
/// cached sweeps); `false` audits the historical full-forward loop on the
/// MGRIT cached hierarchy — so the whole serving stack (embed, solve or
/// cached sweep, logits head, selection) is covered in both modes.
fn audit_decode(incremental: bool) {
    let mut rc = presets::by_name("gpt").expect("gpt preset");
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_dec_layers = 6;
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    let params = ParamStore::init(&rc.model, Init::Default, 5);
    let mut inf = InferSession::from_parts(rc.clone(), params, Box::new(Mgrit)).expect("session");
    inf.set_incremental(incremental);
    let plen = rc.model.seq / 2;
    let prompts: Vec<i32> = vec![1; rc.model.batch * plen];
    let mut out = Vec::new();
    for (label, opts) in [
        ("greedy", DecodeOptions::default()),
        ("top-k", DecodeOptions { top_k: 4, temperature: 0.9, seed: 3, max_new: 0 }),
    ] {
        // warm up: out/scratch sizing, core + Φ scratch pool construction
        // (and, incrementally, the one-time decode-cache slab build)
        for _ in 0..3 {
            inf.generate_into(&prompts, plen, &opts, &mut out).expect("decode");
        }
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..3 {
            inf.generate_into(&prompts, plen, &opts, &mut out).expect("decode");
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            delta, 0,
            "{} decode (incremental={}) allocated {} times over 3 steady-state generate calls",
            label, incremental, delta
        );
    }
}

/// The serve pin: the continuous-batching scheduler's steady-state decode
/// step — empty-queue admission poll, batched forward with per-row
/// cursors, one greedy and one top-k slot sampling side by side, metrics
/// recording — allocates exactly zero times. Retirement and reporting
/// (which build per-request result rows) happen outside the audited
/// window by construction: both requests fill the window, so no slot
/// retires during the audited steps. Audited in both decode modes; with
/// `incremental = true` the audited steps are pure cached O(1) sweeps.
fn audit_serve(incremental: bool) {
    let mut rc = presets::by_name("gpt").expect("gpt preset");
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_dec_layers = 6;
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    let params = ParamStore::init(&rc.model, Init::Default, 5);
    let mut inf = InferSession::from_parts(rc, params, Box::new(Mgrit)).expect("session");
    inf.set_incremental(incremental);
    let mut srv = ServeLoop::new(inf, 4).expect("serve loop");
    // two window-filling requests (prompt 1, seq 8 → 7 decode steps each):
    // one greedy slot and one top-k slot decode side by side
    srv.submit(GenerateRequest::greedy(0, vec![1])).expect("submit");
    srv.submit(GenerateRequest {
        top_k: 4,
        temperature: 0.9,
        seed: 3,
        ..GenerateRequest::greedy(1, vec![2])
    })
    .expect("submit");
    // warm up: admission + cold-row install, core construction, top-k
    // scratch sizing, first-token metrics samples
    for _ in 0..3 {
        srv.step().expect("serve step");
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..3 {
        srv.step().expect("serve step");
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        delta, 0,
        "serve decode step (incremental={}) allocated {} times at steady state",
        incremental, delta
    );
    // drain: both requests retire and report past the audited window
    while srv.active() > 0 {
        srv.step().expect("serve step");
    }
    assert_eq!(srv.take_completed().len(), 2);
}

/// Single test (see module docs): the steady-state hot path is
/// allocation-free — Φ, the solve context on both the single-threaded and
/// the threaded (in-place sweep) backends, the entire train step, the
/// batched decode loop, and the continuous-batching serve step.
#[test]
fn steady_state_hot_path_is_allocation_free() {
    assert!(
        !layertime::fault::armed(),
        "the audit measures the disarmed fast path: one relaxed atomic load per fault point"
    );
    audit_arch(Arch::Encoder);
    audit_arch(Arch::EncDec);
    audit_solve_context(1);
    audit_solve_context(2);
    audit_solve_context(4);
    audit_train_step();
    audit_train_step_dp(2, 2);
    audit_train_step_dp(4, 2);
    audit_decode(true);
    audit_decode(false);
    audit_serve(true);
    audit_serve(false);
}
