//! Zero-allocation audit of the steady-state training hot path.
//!
//! Installs a counting global allocator (this file is its own test binary,
//! and it contains exactly one #[test] so no concurrent test can perturb
//! the counter) and pins three acceptance criteria:
//!
//! 1. once the scratch pool and parameter views are warm,
//!    `RustPropagator::step_into` performs **zero heap allocations** per
//!    step, for both the flat encoder state and the stacked
//!    encoder-decoder state;
//! 2. the persistent solve context performs **zero heap allocations** for
//!    a complete steady-state forward-solve + adjoint-solve + gradients
//!    round (cached hierarchies, workspace handoff, warm-start refresh);
//! 3. a full `Session::train_step` at steady state allocates only from
//!    the documented allowlist below — nothing from the solver side —
//!    and the per-step count is *flat* (no drift across steps).
//!
//! ## train_step allocation allowlist
//!
//! The solve path (embed, buffer sweeps, MGRIT forward/adjoint, gradient
//! accumulation, clipping math, optimizer moments) is allocation-free by
//! construction. What remains, by design outside this PR's scope:
//!
//! * data sampling — `Objective::sample` builds one `TrainBatch`
//!   (tokens/targets/mask vectors, ~3 Vecs for the Tag task);
//! * the loss head — `tag_loss` allocates its logits scratch, the λ_head
//!   cotangent tensor, and the head-gradient vector (~4-6 allocations);
//! * the clip ref-list — one `Vec<&mut [f32]>` per step.
//!
//! `TRAIN_STEP_ALLOC_BUDGET` bounds the sum with headroom; making the
//! objective side workspace-reusing would bring it to literally zero.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use layertime::config::{presets, Arch, MgritConfig, ModelConfig};
use layertime::coordinator::{Mgrit, Session, SolveContext, StepWorkspace, Task};
use layertime::ode::{shared_params, Propagator, RustPropagator};
use layertime::tensor::Tensor;
use layertime::util::rng::Rng;

/// Upper bound on steady-state allocations of one `train_step` (see the
/// allowlist in the module docs; generous headroom over the enumerated
/// sources so task/data tweaks don't flake the audit).
const TRAIN_STEP_ALLOC_BUDGET: u64 = 64;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn tiny_model(arch: Arch) -> ModelConfig {
    ModelConfig {
        arch,
        vocab: 8,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        seq: 4,
        batch: 2,
        n_classes: 2,
        n_enc_layers: if arch == Arch::EncDec { 2 } else { 4 },
        n_dec_layers: if arch == Arch::EncDec { 2 } else { 0 },
        buffer_open: 0,
        buffer_close: 0,
    }
}

fn audit_arch(arch: Arch) {
    let model = tiny_model(arch);
    let mut rng = Rng::new(11);
    let mut layers = Vec::new();
    for l in 0..model.total_layers() {
        let len = if model.arch == Arch::EncDec && l >= model.n_enc_layers {
            model.p_dec()
        } else {
            model.p_enc()
        };
        layers.push(rng.normal_vec(len, 0.1));
    }
    let prop = RustPropagator::new(&model, 1.0, shared_params(layers));
    let z = Tensor::randn(&mut rng, &prop.state_shape(), 0.8);
    let mut out = Tensor::zeros(&prop.state_shape());

    // warm up: the scratch pool allocates its buffers on the first few
    // applications (covering every layer phase) and the pooled buffers
    // then cycle through their slots until every capacity suffices
    for _ in 0..10 {
        for layer in 0..prop.n_steps() {
            prop.step_into(layer, 1.0, &z, &mut out);
        }
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        for layer in 0..prop.n_steps() {
            prop.step_into(layer, 1.0, &z, &mut out);
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{:?}: step_into allocated {} times over {} steady-state steps",
        arch,
        after - before,
        5 * prop.n_steps()
    );
}

/// The persistent-context pin: a steady-state forward + adjoint +
/// gradients round on cached cores allocates nothing at all.
fn audit_solve_context() {
    let model = tiny_model(Arch::Encoder);
    let n = model.total_layers();
    let mut rng = Rng::new(12);
    let layers: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(model.p_enc(), 0.1)).collect();
    let theta_lens: Vec<usize> = layers.iter().map(|t| t.len()).collect();
    let prop = RustPropagator::new(&model, 1.0, shared_params(layers));
    let shape = prop.state_shape();
    let ws = StepWorkspace::new(n, &shape, &shape, &theta_lens, [0, 0, 0, 0]);
    let mut ctx = SolveContext::new(Box::new(Mgrit), ws);
    let cfg = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    let z = Tensor::randn(&mut rng, &shape, 0.8);
    let ct = Tensor::randn(&mut rng, &shape, 1.0);

    let mut round = |ctx: &mut SolveContext| {
        ctx.forward_mid(&prop, &cfg, 0, Some(1), true, false);
        ctx.ws.lams[n].copy_from(&ct);
        ctx.adjoint_mid(&prop, &cfg, 0, Some(1), false);
        ctx.gradients_mid(&prop, 0);
    };

    // warm up: builds both cores, the warm iterate, and the Φ scratch pool
    ctx.ws.states[0].copy_from(&z);
    for _ in 0..5 {
        round(&mut ctx);
    }
    assert_eq!(ctx.core_builds(), 2);

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        round(&mut ctx);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "solve context allocated {} times over 5 steady-state rounds",
        after - before
    );
    assert_eq!(ctx.core_builds(), 2, "steady state must not rebuild cores");
}

/// The full-step pin: per-step allocations stay flat and within the
/// documented allowlist budget.
fn audit_train_step() {
    let mut rc = presets::by_name("mc").expect("mc preset");
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_enc_layers = 8;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.probe_every = 0;
    rc.train.adaptive = false;
    rc.train.warmup = 0;
    let mut s = Session::builder()
        .config(rc)
        .task(Task::Tag)
        .backend(Box::new(Mgrit))
        .build()
        .expect("session");

    // warm up: lazy core construction, warm iterate, scratch pool growth
    for _ in 0..4 {
        s.train_step();
    }

    let mut deltas = [0u64; 2];
    for d in deltas.iter_mut() {
        let before = ALLOCS.load(Ordering::SeqCst);
        s.train_step();
        *d = ALLOCS.load(Ordering::SeqCst) - before;
    }
    assert_eq!(
        deltas[0], deltas[1],
        "per-step allocations must be flat at steady state: {:?}",
        deltas
    );
    assert!(
        deltas[0] <= TRAIN_STEP_ALLOC_BUDGET,
        "train_step allocated {} times; allowlist budget is {} (see module docs)",
        deltas[0],
        TRAIN_STEP_ALLOC_BUDGET
    );
}

/// Single test (see module docs): the steady-state hot path is
/// allocation-free (Φ and the solve context) and the full train step
/// stays within the documented allowlist.
#[test]
fn steady_state_hot_path_is_allocation_free() {
    audit_arch(Arch::Encoder);
    audit_arch(Arch::EncDec);
    audit_solve_context();
    audit_train_step();
}
