//! Checkpoint round-trip acceptance: `save → resume` continues a training
//! run **bitwise identically** — the resumed `Session` must produce the
//! exact `StepRecord` stream (loss/acc/lr/ρ bits) and final parameters of
//! the uninterrupted run. Covers weights, optimizer moments (Adam m/v/t),
//! the training RNG stream (incl. the Box-Muller spare), the §3.2.3
//! controller (batch counter, ρ-history, sticky switch), the divergence
//! watchdog's initial-loss anchor, and the TorchBraid warm-start iterate.
//! Also pins the inference path on checkpoints and the corrupt-file /
//! config-mismatch error surfaces end-to-end.

use layertime::checkpoint::Checkpoint;
use layertime::config::{presets, MgritConfig, OptKind, RunConfig};
use layertime::coordinator::{Session, StepRecord, Task};
use layertime::infer::{DecodeOptions, InferSession};

fn tmp(name: &str) -> String {
    let p = std::env::temp_dir().join(name);
    p.to_str().unwrap().to_string()
}

/// Tiny but feature-dense config: MGRIT forward+adjoint (so the warm
/// iterate matters), Adam (so moments matter), adaptive probes on a short
/// cadence (so controller state matters), warmup+cosine LR.
fn tiny_rc(name: &str, task_steps: usize) -> RunConfig {
    let mut rc = presets::by_name(name).unwrap();
    presets::shrink_for_bench(&mut rc);
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.steps = task_steps;
    rc.train.opt = OptKind::Adam;
    rc.train.warmup = 2;
    rc.train.adaptive = true;
    rc.train.probe_every = 3;
    rc.train.eval_every = 1000; // drive train_step directly
    rc
}

fn bits(r: &StepRecord) -> (usize, u32, u32, u32, bool, Option<u64>, Option<u64>) {
    (
        r.step,
        r.loss.to_bits(),
        r.acc.to_bits(),
        r.lr.to_bits(),
        r.serial,
        r.rho_fwd.map(f64::to_bits),
        r.rho_bwd.map(f64::to_bits),
    )
}

fn params_bits(s: &Session) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = s
        .params
        .layers
        .read()
        .unwrap()
        .iter()
        .map(|l| l.iter().map(|x| x.to_bits()).collect())
        .collect();
    for g in [&s.params.w_emb, &s.params.w_pos, &s.params.w_out, &s.params.w_cls] {
        out.push(g.iter().map(|x| x.to_bits()).collect());
    }
    out
}

/// Run `total` steps uninterrupted; run `cut` steps, save, resume, run the
/// rest; every record and the final parameters must match bitwise.
fn roundtrip_case(rc: RunConfig, task: Task, total: usize, cut: usize, file: &str) {
    let mut a = Session::builder().config(rc.clone()).task(task).build().unwrap();
    let recs_a: Vec<StepRecord> = (0..total).map(|_| a.train_step()).collect();

    let mut b = Session::builder().config(rc).task(task).build().unwrap();
    for _ in 0..cut {
        b.train_step();
    }
    let path = tmp(file);
    b.save(&path).unwrap();
    // keep training `b` past the save too: saving must not perturb it
    let recs_b_tail: Vec<StepRecord> = (0..total - cut).map(|_| b.train_step()).collect();
    for (x, y) in recs_a[cut..].iter().zip(&recs_b_tail) {
        assert_eq!(bits(x), bits(y), "saving mid-run must not perturb the run");
    }

    let mut c = Session::resume(&path).unwrap();
    assert_eq!(c.step(), cut, "resume must pick up at the saved step");
    let recs_c: Vec<StepRecord> = (0..total - cut).map(|_| c.train_step()).collect();
    for (x, y) in recs_a[cut..].iter().zip(&recs_c) {
        assert_eq!(
            bits(x),
            bits(y),
            "resumed step records must match the uninterrupted run bitwise"
        );
    }
    assert_eq!(
        params_bits(&a),
        params_bits(&c),
        "final parameters must match the uninterrupted run bitwise"
    );
    assert_eq!(
        a.controller.history(),
        c.controller.history(),
        "probe history must continue seamlessly"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn resume_is_bitwise_identical_encoder_tagging() {
    // MC task: encoder arch, MGRIT both directions, warm starts active
    roundtrip_case(tiny_rc("mc", 12), Task::Tag, 12, 5, "lt_rt_mc.ltcp");
}

#[test]
fn resume_is_bitwise_identical_encdec_dp2() {
    // MT task: stacked EncDec state + dp micro-batch stash/fold on top
    let mut rc = tiny_rc("mt", 8);
    rc.dp_degree = 2;
    roundtrip_case(rc, Task::Translate, 8, 3, "lt_rt_mt.ltcp");
}

#[test]
fn resume_is_bitwise_identical_decoder_buffers() {
    // GPT task: decoder arch with serial buffer layers and a serial
    // forward (buffer sweeps + mid adjoint solve through the checkpoint)
    let mut rc = tiny_rc("gpt", 8);
    rc.model.n_dec_layers = 6;
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    rc.mgrit.fwd_iters = None;
    roundtrip_case(rc, Task::Lm, 8, 4, "lt_rt_gpt.ltcp");
}

#[test]
fn resume_after_a_forced_serial_switch_stays_serial() {
    let mut rc = tiny_rc("mc", 10);
    rc.train.probe_every = 2;
    let mut s = Session::builder().config(rc.clone()).task(Task::Tag).build().unwrap();
    for _ in 0..3 {
        s.train_step();
    }
    s.controller.force_serial(&mut s.rc.mgrit);
    s.train_step();
    let path = tmp("lt_rt_serial.ltcp");
    s.save(&path).unwrap();
    let want: Vec<_> = (0..3).map(|_| bits(&s.train_step())).collect();
    let mut r = Session::resume(&path).unwrap();
    assert!(r.controller.is_serial(), "the sticky switch must survive the round-trip");
    assert!(r.rc.mgrit.is_serial(), "the mutated MGRIT config must survive too");
    let got: Vec<_> = (0..3).map(|_| bits(&r.train_step())).collect();
    assert_eq!(want, got);
    std::fs::remove_file(&path).ok();
}

#[test]
fn inference_runs_off_a_training_checkpoint() {
    // the train --save → generate/predict pipeline, in-process
    let rc = tiny_rc("mc", 4);
    let mut s = Session::builder().config(rc.clone()).task(Task::Tag).build().unwrap();
    for _ in 0..4 {
        s.train_step();
    }
    let path = tmp("lt_rt_infer.ltcp");
    s.save(&path).unwrap();
    let mut inf = InferSession::from_checkpoint(&path).unwrap();
    let (b, seq) = (inf.rc.model.batch, inf.rc.model.seq);
    let tokens: Vec<i32> = (0..b * seq).map(|i| (i % 7) as i32).collect();
    let preds = inf.predict(&tokens).unwrap();
    assert_eq!(preds.len(), b * seq);
    // deterministic across a fresh load of the same file
    let mut inf2 = InferSession::from_checkpoint(&path).unwrap();
    assert_eq!(preds, inf2.predict(&tokens).unwrap());
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupt_truncated_and_mismatched_files_error_cleanly() {
    let rc = tiny_rc("mc", 3);
    let mut s = Session::builder().config(rc).task(Task::Tag).build().unwrap();
    s.train_step();
    let path = tmp("lt_rt_err.ltcp");
    s.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // truncated file
    let cut_path = tmp("lt_rt_err_cut.ltcp");
    std::fs::write(&cut_path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(Session::resume(&cut_path).is_err());
    assert!(InferSession::from_checkpoint(&cut_path).is_err());

    // flipped byte → checksum failure
    let mut bad = bytes.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x10;
    let bad_path = tmp("lt_rt_err_bad.ltcp");
    std::fs::write(&bad_path, &bad).unwrap();
    // {:#} renders the anyhow context chain (the root cause names the checksum)
    let err = format!("{:#}", Session::resume(&bad_path).unwrap_err());
    assert!(err.contains("checksum"), "{}", err);

    // config mismatch: a checkpoint whose tensor table disagrees with its
    // own config (decode catches it before any session state is built)
    let mut ck = Checkpoint::read(&path).unwrap();
    ck.layers[1].pop();
    let mm_path = tmp("lt_rt_err_mm.ltcp");
    ck.write(&mm_path).unwrap();
    let err = format!("{:#}", Session::resume(&mm_path).unwrap_err());
    assert!(err.contains("param.layer.1"), "{}", err);

    // missing file
    assert!(Session::resume(&tmp("lt_rt_err_missing.ltcp")).is_err());

    for p in [&path, &cut_path, &bad_path, &mm_path] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn generate_after_save_works_for_the_decoder_preset() {
    let mut rc = tiny_rc("gpt", 3);
    rc.model.n_dec_layers = 4;
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    let mut s = Session::builder().config(rc).task(Task::Lm).build().unwrap();
    for _ in 0..3 {
        s.train_step();
    }
    let path = tmp("lt_rt_gen.ltcp");
    s.save(&path).unwrap();
    let mut inf = InferSession::from_checkpoint(&path).unwrap();
    let (b, seq, vocab) = (inf.rc.model.batch, inf.rc.model.seq, inf.rc.model.vocab);
    let plen = seq / 2;
    let prompts: Vec<i32> = (0..b * plen).map(|i| (i % 5) as i32).collect();
    let out = inf.generate(&prompts, plen, &DecodeOptions::default()).unwrap();
    assert_eq!(out.len(), b * seq);
    assert!(out.iter().all(|&t| (t as usize) < vocab));
    std::fs::remove_file(&path).ok();
}
