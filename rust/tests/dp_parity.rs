//! Data-parallel parity: the acceptance property of the real-DP pass.
//!
//! `--dp N` replicas may execute on any worker split — one serial lane
//! (`--dp-workers 1`, the old micro-batch loop order), several concurrent
//! replica lanes, or the simulator auto-split — and every split must
//! produce **bitwise-identical** training: the same `StepRecord` stream,
//! the same final parameters, the same checkpoint bytes. The pinned
//! replica-summation order (strictly left-associated, replica-ascending —
//! see `parallel/mod.rs` §"DP×LP execution") is what makes this hold for
//! f32 gradients.
//!
//! The chaos case extends policy 3 to replica groups: a pooled-sweep
//! panic inside ONE replica's layer-parallel pool is retried on a rebuilt
//! pool without perturbing the other replicas' lanes, and the whole run
//! stays bitwise clean.
//!
//! The fault registry is process-global, so every test here serializes on
//! one lock and resets the registry on entry and exit (same discipline as
//! `chaos.rs`).

use std::sync::Mutex;

use layertime::config::{presets, MgritConfig, RunConfig};
use layertime::coordinator::{Session, StepRecord, Task};
use layertime::fault;
use layertime::parallel::worker_splits;

static DP_LOCK: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    let g = DP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::reset();
    g
}

/// The `mc` preset at parity-test scale with `dp` data-parallel replicas.
fn dp_rc(seed: u64, dp: usize, fwd: Option<usize>, bwd: Option<usize>) -> RunConfig {
    let mut rc = presets::by_name("mc").unwrap();
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_enc_layers = 8;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: fwd, bwd_iters: bwd, fcf: true };
    rc.train.steps = 3;
    rc.train.eval_every = 100;
    rc.train.probe_every = 0;
    rc.train.adaptive = false;
    rc.train.warmup = 0;
    rc.train.seed = seed;
    rc.dp_degree = dp;
    rc
}

type RecBits = (usize, u32, u32, u32, bool, Option<u64>, Option<u64>);

fn bits(r: &StepRecord) -> RecBits {
    (
        r.step,
        r.loss.to_bits(),
        r.acc.to_bits(),
        r.lr.to_bits(),
        r.serial,
        r.rho_fwd.map(f64::to_bits),
        r.rho_bwd.map(f64::to_bits),
    )
}

fn params_bits(s: &Session) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = s
        .params
        .layers
        .read()
        .unwrap()
        .iter()
        .map(|l| l.iter().map(|x| x.to_bits()).collect())
        .collect();
    for g in [&s.params.w_emb, &s.params.w_pos, &s.params.w_out, &s.params.w_cls] {
        out.push(g.iter().map(|x| x.to_bits()).collect());
    }
    out
}

/// Train `steps` steps on a given worker split. `dp_workers = None` takes
/// the simulator auto-split path.
fn run_split(
    rc: &RunConfig,
    workers: usize,
    dp_workers: Option<usize>,
    steps: usize,
) -> (Session, Vec<RecBits>) {
    let mut b = Session::builder().config(rc.clone()).task(Task::Tag).workers(workers);
    if let Some(d) = dp_workers {
        b = b.dp_workers(d);
    }
    let mut s = b.build().unwrap();
    let recs = (0..steps).map(|_| bits(&s.train_step())).collect();
    (s, recs)
}

#[test]
fn sharded_dp_matches_serial_dp_bitwise() {
    let _g = guard();
    for dp in [1usize, 2, 4] {
        let rc = dp_rc(11 + dp as u64, dp, Some(2), Some(1));
        // serial-dp reference: one replica lane folding in ascending order
        let (base_s, base) = run_split(&rc, 2, Some(1), 3);
        let base_params = params_bits(&base_s);
        for workers in [2usize, 4, 8] {
            // every divisor split the CLI can reach, plus the auto-split
            let mut lanes: Vec<Option<usize>> =
                worker_splits(workers, dp).iter().map(|t| Some(t.dp)).collect();
            lanes.push(None);
            for d in lanes {
                let (s, recs) = run_split(&rc, workers, d, 3);
                let tag = format!("dp={} workers={} dp_workers={:?}", dp, workers, d);
                assert_eq!(base, recs, "{}: StepRecord stream must be bitwise identical", tag);
                assert_eq!(
                    base_params,
                    params_bits(&s),
                    "{}: final parameters must be bitwise identical",
                    tag
                );
            }
        }
    }
}

#[test]
fn exact_mode_dp_is_split_invariant_too() {
    let _g = guard();
    // serial propagation (no MGRIT iterations, no warm iterate): the fold
    // order is the only thing that could diverge — pin it there as well
    let rc = dp_rc(5, 2, None, None);
    let (a_s, a) = run_split(&rc, 2, Some(1), 3);
    let (b_s, b) = run_split(&rc, 2, Some(2), 3);
    assert_eq!(a, b);
    assert_eq!(params_bits(&a_s), params_bits(&b_s));
}

#[test]
fn dp_checkpoint_bytes_are_split_invariant() {
    let _g = guard();
    let dir = std::env::temp_dir().join(format!("lt_dp_parity_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let rc = dp_rc(23, 2, Some(2), Some(1));
    let (mut serial, _) = run_split(&rc, 4, Some(1), 3);
    let (mut sharded, _) = run_split(&rc, 4, Some(2), 3);
    let p1 = dir.join("serial.ltcp");
    let p2 = dir.join("sharded.ltcp");
    serial.save(p1.to_str().unwrap()).unwrap();
    sharded.save(p2.to_str().unwrap()).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    assert_eq!(
        b1, b2,
        "checkpoints (params, moments, RNG, replica-major warm section) must be byte-identical \
         across worker splits"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replica_group_sweep_panic_recovers_bitwise() {
    let _g = guard();
    // workers=4, dp=2, dp-workers=2: two concurrent replica lanes, each
    // driving a 2-worker relaxation pool. The injected panic lands in ONE
    // replica's pooled FCF sweep (whichever lane reaches the process-global
    // fault counter third); policy 3 retries that replica's sweep on a
    // rebuilt pool while the other lane is untouched.
    let rc = dp_rc(31, 2, Some(1), Some(1));
    let (clean_s, clean) = run_split(&rc, 4, Some(2), 4);

    fault::arm("pool.sweep_panic@step=3").unwrap();
    let (hurt_s, hurt) = run_split(&rc, 4, Some(2), 4);

    assert_eq!(fault::fired("pool.sweep_panic"), 1);
    assert!(
        fault::events().iter().any(|e| e.point == "pool.sweep" && e.action == "sweep_retry"),
        "the recovery must surface as a typed sweep_retry event"
    );
    assert_eq!(clean, hurt, "the retried replica sweep must be bitwise clean");
    assert_eq!(params_bits(&clean_s), params_bits(&hurt_s));
    fault::reset();
}
