//! Integration: load the real AOT artifacts through PJRT and pin their
//! numerics against the independent pure-Rust reference transformer.
//!
//! These tests skip (pass vacuously with a notice) when `artifacts/` has not
//! been built — run `make artifacts` first for full coverage.

use layertime::reference::{self, RefDims};
use layertime::runtime::{Value, XlaEngine};
use layertime::tensor::Tensor;
use layertime::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("LAYERTIME_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir);
        None
    }
}

fn dims_from(engine: &XlaEngine) -> RefDims {
    let m = engine.manifest();
    RefDims {
        batch: m.cfg("batch").unwrap(),
        seq: m.cfg("seq").unwrap(),
        d_model: m.cfg("d_model").unwrap(),
        n_heads: m.cfg("n_heads").unwrap(),
        d_ff: m.cfg("d_ff").unwrap(),
    }
}

#[test]
fn enc_step_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).unwrap();
    let dm = dims_from(&engine);
    let p_enc = engine.manifest().cfg("p_enc").unwrap();

    let mut rng = Rng::new(11);
    let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
    let theta = rng.normal_vec(p_enc, 0.05);
    let h = 0.5f32;

    for (entry, causal) in [("enc_step", false), ("causal_step", true)] {
        let out = engine
            .call(
                entry,
                &[
                    Value::F32(x.clone()),
                    Value::F32(Tensor::from_vec(theta.clone(), &[p_enc])),
                    Value::scalar(h),
                ],
            )
            .unwrap();
        let want = reference::enc_step_fwd(&x, &theta, h, &dm, causal);
        assert!(
            out[0].allclose(&want, 2e-4, 2e-4),
            "{}: max diff {}",
            entry,
            out[0].max_abs_diff(&want)
        );
    }
}

#[test]
fn enc_step_vjp_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).unwrap();
    let dm = dims_from(&engine);
    let p_enc = engine.manifest().cfg("p_enc").unwrap();

    let mut rng = Rng::new(12);
    let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
    let theta = rng.normal_vec(p_enc, 0.05);
    let ct = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
    let h = 0.5f32;

    let out = engine
        .call(
            "enc_step_vjp",
            &[
                Value::F32(x.clone()),
                Value::F32(Tensor::from_vec(theta.clone(), &[p_enc])),
                Value::scalar(h),
                Value::F32(ct.clone()),
            ],
        )
        .unwrap();
    let (lam, gtheta) = reference::enc_step_bwd(&x, &theta, h, &dm, false, &ct);
    assert!(out[0].allclose(&lam, 5e-4, 5e-4), "lambda diff {}", out[0].max_abs_diff(&lam));
    let g = Tensor::from_vec(gtheta, &[p_enc]);
    assert!(out[1].allclose(&g, 5e-4, 5e-4), "grad diff {}", out[1].max_abs_diff(&g));
}

#[test]
fn dec_step_and_vjp_match_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).unwrap();
    let dm = dims_from(&engine);
    let p_dec = engine.manifest().cfg("p_dec").unwrap();

    let mut rng = Rng::new(13);
    let y = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
    let xe = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
    let theta = rng.normal_vec(p_dec, 0.05);
    let h = 1.0f32;

    let out = engine
        .call(
            "dec_step",
            &[
                Value::F32(y.clone()),
                Value::F32(xe.clone()),
                Value::F32(Tensor::from_vec(theta.clone(), &[p_dec])),
                Value::scalar(h),
            ],
        )
        .unwrap();
    let want = reference::dec_step_fwd(&y, &xe, &theta, h, &dm, dm.seq);
    assert!(out[0].allclose(&want, 2e-4, 2e-4), "dec diff {}", out[0].max_abs_diff(&want));

    let ct = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
    let out = engine
        .call(
            "dec_step_vjp",
            &[
                Value::F32(y.clone()),
                Value::F32(xe.clone()),
                Value::F32(Tensor::from_vec(theta.clone(), &[p_dec])),
                Value::scalar(h),
                Value::F32(ct.clone()),
            ],
        )
        .unwrap();
    let (dy, dxe, gt) = reference::dec_step_bwd(&y, &xe, &theta, h, &dm, dm.seq, &ct);
    assert!(out[0].allclose(&dy, 5e-4, 5e-4), "dy diff {}", out[0].max_abs_diff(&dy));
    assert!(out[1].allclose(&dxe, 5e-4, 5e-4), "dxe diff {}", out[1].max_abs_diff(&dxe));
    let gt = Tensor::from_vec(gt, &[p_dec]);
    assert!(out[2].allclose(&gt, 5e-4, 5e-4), "gt diff {}", out[2].max_abs_diff(&gt));
}

#[test]
fn loss_entry_points_run() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).unwrap();
    let m = engine.manifest();
    let (b, s, d, v) =
        (m.cfg("batch").unwrap(), m.cfg("seq").unwrap(), m.cfg("d_model").unwrap(), m.cfg("vocab").unwrap());

    let mut rng = Rng::new(14);
    let x = Tensor::randn(&mut rng, &[b, s, d], 0.5);
    let w = Tensor::randn(&mut rng, &[d, v], 0.1);
    let targets: Vec<i32> = (0..b * s).map(|_| rng.range(v) as i32).collect();
    let mask = Tensor::from_vec(vec![1.0; b * s], &[b, s]);

    let out = engine
        .call(
            "lm_loss_vjp",
            &[
                Value::F32(x.clone()),
                Value::F32(w.clone()),
                Value::I32(targets.clone(), vec![b, s]),
                Value::F32(mask),
            ],
        )
        .unwrap();
    let loss = out[0].item();
    assert!(loss.is_finite() && loss > 0.0, "loss {}", loss);
    // random init: loss near ln(vocab)
    assert!((loss - (v as f32).ln()).abs() < 1.0, "loss {} vs ln V {}", loss, (v as f32).ln());
    // lambda has x's shape, grad has w's shape
    assert_eq!(out[2].shape(), x.shape());
    assert_eq!(out[3].shape(), w.shape());
}

#[test]
fn embed_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).unwrap();
    let m = engine.manifest();
    let (b, s, d, v) =
        (m.cfg("batch").unwrap(), m.cfg("seq").unwrap(), m.cfg("d_model").unwrap(), m.cfg("vocab").unwrap());

    let mut rng = Rng::new(15);
    let we = Tensor::randn(&mut rng, &[v, d], 1.0);
    let wp = Tensor::randn(&mut rng, &[s, d], 1.0);
    let toks: Vec<i32> = (0..b * s).map(|_| rng.range(v) as i32).collect();
    let out = engine
        .call(
            "embed",
            &[Value::I32(toks.clone(), vec![b, s]), Value::F32(we.clone()), Value::F32(wp.clone())],
        )
        .unwrap();
    // spot-check position (0, 0)
    let tok0 = toks[0] as usize;
    for i in 0..d {
        let want = we.data()[tok0 * d + i] + wp.data()[i];
        assert!((out[0].data()[i] - want).abs() < 1e-5);
    }
}

#[test]
fn executable_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir).unwrap();
    let bad = Tensor::zeros(&[1, 2, 3]);
    let err = engine.call("enc_step", &[Value::F32(bad)]).unwrap_err();
    let msg = format!("{}", err);
    assert!(msg.contains("expected"), "{}", msg);
}
