//! Paper-level properties, asserted end to end over the transformer
//! propagator (artifact-free):
//!
//! * MGRIT iteration count monotonically controls gradient bias (§3.2.3's
//!   premise);
//! * FMG/nested-iteration initialization beats cold start at solver level;
//! * warm-starting across batches (TorchBraid-style) helps;
//! * the threaded slab executor reproduces the engine's relaxation on a
//!   transformer-scale problem;
//! * the convergence factor predicts contraction (ρ < 1 ⇔ residual drops).

use layertime::config::{Arch, MgritConfig, ModelConfig};
use layertime::mgrit::MgritSolver;
use layertime::ode::{shared_params, Propagator, RustPropagator};
use layertime::parallel::exec::{parallel_fc_relax, serial_fc_relax};
use layertime::tensor::Tensor;
use layertime::util::rng::Rng;

fn model(n_layers: usize) -> ModelConfig {
    ModelConfig {
        arch: Arch::Encoder,
        vocab: 16,
        d_model: 8,
        n_heads: 2,
        d_ff: 16,
        seq: 4,
        batch: 2,
        n_classes: 4,
        n_enc_layers: n_layers,
        n_dec_layers: 0,
        buffer_open: 0,
        buffer_close: 0,
    }
}

fn prop_h(n_layers: usize, seed: u64, std: f32, h: f32) -> RustPropagator {
    let m = model(n_layers);
    let mut rng = Rng::new(seed);
    let params: Vec<Vec<f32>> =
        (0..n_layers).map(|_| rng.normal_vec(m.p_enc(), std)).collect();
    RustPropagator::new(&m, h, shared_params(params))
}

fn prop(n_layers: usize, seed: u64, std: f32) -> RustPropagator {
    prop_h(n_layers, seed, std, 0.25)
}

#[test]
fn gradient_bias_is_monotone_in_iterations() {
    // ‖g_k − g_exact‖ must not increase with k — the §3.2.3 control knob.
    let p = prop(16, 1, 0.1);
    let mut rng = Rng::new(2);
    let z0 = Tensor::randn(&mut rng, &p.state_shape(), 1.0);
    let ct = Tensor::randn(&mut rng, &p.state_shape(), 1.0);
    let solver = MgritSolver::new(
        &p,
        MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true },
    );
    let (states, _) = solver.forward(&z0, None, None, false);
    let (lam_exact, _) = solver.adjoint(&states, &ct, None, false);
    let g_exact = solver.gradients(&states, &lam_exact);
    let err = |k: usize| -> f64 {
        let (lam, _) = solver.adjoint(&states, &ct, Some(k), false);
        let g = solver.gradients(&states, &lam);
        let mut s = 0.0f64;
        for (a, b) in g.iter().zip(&g_exact) {
            for (x, y) in a.iter().zip(b.iter()) {
                s += ((x - y) as f64).powi(2);
            }
        }
        s.sqrt()
    };
    let errs: Vec<f64> = [1, 2, 3, 4].iter().map(|&k| err(k)).collect();
    for w in errs.windows(2) {
        assert!(w[1] <= w[0] * 1.01, "bias must shrink: {:?}", errs);
    }
    assert!(errs[3] < errs[0] * 0.5, "4 iters should beat 1 clearly: {:?}", errs);
}

#[test]
fn fmg_solve_converges_on_transformer() {
    // Nested-iteration (FMG) initialization: on the stable linear model it
    // provably beats a cold start (pinned in mgrit::core tests); on a
    // contractive transformer the cold start is already near the
    // trajectory, so here we assert the solver-level property that holds
    // universally — forward_fmg converges to the exact serial solution.
    let p = prop_h(32, 3, 0.2, 0.5);
    let mut rng = Rng::new(4);
    let z0 = Tensor::randn(&mut rng, &p.state_shape(), 1.0);
    let solver = MgritSolver::new(
        &p,
        MgritConfig { cf: 2, levels: 3, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true },
    );
    let (serial, _) = solver.forward(&z0, None, None, false);
    let (fmg, stats) = solver.forward_fmg(&z0, 4, true);
    assert!(stats.residuals.last().unwrap() < &1e-3, "{:?}", stats.residuals);
    let rel = fmg.last().unwrap().dist(serial.last().unwrap())
        / serial.last().unwrap().norm().max(1e-9);
    assert!(rel < 1e-3, "relative error {}", rel);
}

#[test]
fn warm_start_from_previous_batch_helps() {
    // TorchBraid-style: warm-start with a slightly different batch's
    // converged states still beats cold start.
    let p = prop_h(16, 5, 0.3, 1.0);
    let mut rng = Rng::new(6);
    let z0_a = Tensor::randn(&mut rng, &p.state_shape(), 1.0);
    let mut z0_b = z0_a.clone();
    z0_b.axpy(0.2, &Tensor::randn(&mut rng, &p.state_shape(), 1.0));
    let solver = MgritSolver::new(
        &p,
        MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true },
    );
    let (states_a, _) = solver.forward(&z0_a, Some(4), None, false);
    let (_, cold) = solver.forward(&z0_b, Some(1), None, true);
    let (_, warm) = solver.forward(&z0_b, Some(1), Some(&states_a), true);
    assert!(
        warm.residuals[0] < cold.residuals[0],
        "warm {} vs cold {}",
        warm.residuals[0],
        cold.residuals[0]
    );
}

#[test]
fn conv_factor_below_one_implies_contraction() {
    let p = prop(32, 7, 0.1);
    let mut rng = Rng::new(8);
    let z0 = Tensor::randn(&mut rng, &p.state_shape(), 1.0);
    let solver = MgritSolver::new(
        &p,
        MgritConfig { cf: 4, levels: 2, fwd_iters: Some(4), bwd_iters: Some(1), fcf: true },
    );
    let (_, stats) = solver.forward(&z0, Some(4), None, true);
    let rho = stats.conv_factor().unwrap();
    assert!(rho < 1.0, "healthy regime should contract, rho={}", rho);
    // residual history must actually decrease when rho < 1
    for w in stats.residuals.windows(2) {
        assert!(w[1] <= w[0] * 1.01, "{:?}", stats.residuals);
    }
}

#[test]
fn threaded_slab_executor_matches_engine_on_transformer_phi() {
    // the channel-fabric execution path reproduces serial FCF relaxation
    // with a real transformer Φ (thread-safe closure over cloned params)
    let m = model(16);
    let mut rng = Rng::new(9);
    let theta = rng.normal_vec(m.p_enc(), 0.1);
    let dims = layertime::reference::RefDims {
        batch: m.batch,
        seq: m.seq,
        d_model: m.d_model,
        n_heads: m.n_heads,
        d_ff: m.d_ff,
    };
    let shape = [m.batch, m.seq, m.d_model];
    let step = move |_layer: usize, z: &[f32]| -> Vec<f32> {
        let t = Tensor::from_vec(z.to_vec(), &shape);
        layertime::reference::enc_step_fwd(&t, &theta, 0.25, &dims, false).into_vec()
    };
    let n = 16;
    let w: Vec<Vec<f32>> =
        (0..=n).map(|_| rng.normal_vec(m.batch * m.seq * m.d_model, 1.0)).collect();
    let serial = serial_fc_relax(w.clone(), 4, &step);
    let parallel =
        parallel_fc_relax(w, None, 4, 4, |l: usize, z: &Vec<f32>, out: &mut Vec<f32>| {
            *out = step(l, z)
        });
    for (a, b) in parallel.iter().zip(&serial) {
        assert_eq!(a, b, "threaded execution must be bitwise identical");
    }
}
