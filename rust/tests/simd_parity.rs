//! SIMD-vs-scalar kernel parity suite (the `--features simd` contract).
//!
//! Pins the two numerical contract classes of the dispatched kernel layer
//! (see `tensor/ops.rs` and `tensor/simd.rs`):
//!
//! * **Bitwise** — `mm_into` / `mm_at_into` must equal the always-scalar
//!   kernels bit for bit on every shape (the SIMD lanes use separate
//!   mul/add roundings in ascending-k order, never FMA);
//! * **Reassociated** — `mm_bt_into`, row softmax, LayerNorm, and GELU may
//!   regroup/fuse, pinned by NaN-mask + bounded-ulp parity against the
//!   scalar kernels, plus the *shape-independence* invariants incremental
//!   decode rests on: an element's bits depend only on its own
//!   row/contraction inputs, never on the row count or column count.
//!
//! Shapes are deliberately ragged (odd m/k/n, sub-lane rows, the cached
//! m = 1 single-position decode shapes). Every test also passes without
//! the feature (or on non-AVX2 hosts): the dispatched kernels *are* the
//! scalar kernels there, so the comparisons hold trivially.
//!
//! Tests that flip the process-wide `set_force_scalar` switch — and the
//! kernel comparisons that depend on it staying off — serialize on one
//! mutex, because the test harness runs tests on parallel threads.

use std::sync::Mutex;

use layertime::config::{presets, Arch, MgritConfig};
use layertime::coordinator::{Mgrit, Session, Task};
use layertime::infer::{DecodeOptions, InferSession};
use layertime::model::{Init, ParamStore};
use layertime::reference::{gelu, gelu_row, layer_norm_fwd_into};
use layertime::tensor::{
    mm_at_into, mm_at_into_scalar, mm_bt_into, mm_bt_into_scalar, mm_into, mm_into_scalar,
    set_force_scalar, softmax_row, softmax_row_scalar,
};
use layertime::util::proptest::forall;

/// Serializes every test in this binary: `set_force_scalar` is process
/// state, and the dispatched-vs-scalar comparisons assume it is off.
static DISPATCH_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    DISPATCH_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Bit patterns of a float slice — "bitwise equal" means equal here, which
/// is stricter than `==` on f32 (it distinguishes -0.0 from +0.0 and does
/// not equate NaNs away).
fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Ragged shape sampler: sub-lane sizes, lane multiples, odd remainders,
/// and the cached-decode m = 1 row shape all get coverage.
fn ragged(rng: &mut layertime::util::rng::Rng) -> (usize, usize, usize) {
    let pick = |rng: &mut layertime::util::rng::Rng| match rng.range(4) {
        0 => 1 + rng.range(7),        // below one lane
        1 => 8 * (1 + rng.range(3)),  // exact lanes
        2 => 9 + rng.range(25),       // lanes + remainder
        _ => 1,                       // single row/column (decode shape)
    };
    (pick(rng), pick(rng), pick(rng))
}

/// The kill switch round-trips, and forcing scalar makes the dispatched
/// kernels literally the scalar kernels (pinned on mm_bt, the kernel whose
/// two paths round differently, so the comparison is meaningful).
#[test]
fn force_scalar_round_trips_and_forces_the_scalar_kernels() {
    let _g = lock();
    let mut rng = layertime::util::rng::Rng::new(1);
    let (m, k, n) = (5, 19, 13);
    let a = rng.normal_vec(m * k, 1.0);
    let bt = rng.normal_vec(n * k, 1.0);
    let mut want = vec![0.0; m * n];
    mm_bt_into_scalar(&a, &bt, m, k, n, &mut want, false);

    set_force_scalar(true);
    assert!(!layertime::tensor::simd_active(), "force_scalar must disable dispatch");
    let mut got = vec![0.0; m * n];
    mm_bt_into(&a, &bt, m, k, n, &mut got, false);
    set_force_scalar(false);
    assert_eq!(bits(&got), bits(&want), "forced-scalar dispatch must be the scalar kernel");
}

#[test]
fn mm_and_mm_at_are_bitwise_identical_to_scalar() {
    let _g = lock();
    forall("simd-mm-bitwise", 60, |rng| {
        let (m, k, n) = ragged(rng);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        // accumulate on top of a shared non-zero base: acc = true is the
        // hot-path mode and must stay bitwise too
        let base = rng.normal_vec(m * n, 0.5);

        let mut got = base.clone();
        let mut want = base.clone();
        mm_into(&a, &b, m, k, n, &mut got, true);
        mm_into_scalar(&a, &b, m, k, n, &mut want, true);
        assert_eq!(bits(&got), bits(&want), "mm_into m={} k={} n={}", m, k, n);

        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let mut got = base.clone();
        let mut want = base;
        mm_at_into(&at, &b, k, m, n, &mut got, true);
        mm_at_into_scalar(&at, &b, k, m, n, &mut want, true);
        assert_eq!(bits(&got), bits(&want), "mm_at_into m={} k={} n={}", m, k, n);
    });
}

#[test]
fn mm_bt_matches_scalar_within_ulp_and_nan_mask() {
    let _g = lock();
    forall("simd-mm-bt-ulp", 60, |rng| {
        let (m, k, n) = ragged(rng);
        let mut a = rng.normal_vec(m * k, 1.0);
        let mut bt = rng.normal_vec(n * k, 1.0);
        if rng.range(3) == 0 {
            // NaN/inf mask parity on a sprinkle of specials
            a[rng.range(m * k)] = f32::NAN;
            bt[rng.range(n * k)] = f32::INFINITY;
        }
        let mut got = vec![0.0; m * n];
        let mut want = vec![0.0; m * n];
        mm_bt_into(&a, &bt, m, k, n, &mut got, false);
        mm_bt_into_scalar(&a, &bt, m, k, n, &mut want, false);
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(x.is_nan(), y.is_nan(), "mm_bt NaN mask at {} ({}x{}x{})", i, m, k, n);
            if !y.is_nan() {
                assert!(
                    (x - y).abs() <= 1e-4 + 1e-4 * y.abs() || (x.is_infinite() && x == *y),
                    "mm_bt[{i}] = {x} vs scalar {y} (m={m} k={k} n={n})"
                );
            }
        }
    });
}

/// The decode-cache invariant for attention scores: an element's bits
/// depend only on its own (query row, key row) contraction — so a cached
/// m = 1 step over a column prefix reproduces the full board bit for bit
/// *within the same build* (scalar or SIMD).
#[test]
fn mm_bt_element_bits_are_independent_of_board_shape() {
    let _g = lock();
    forall("simd-mm-bt-shape-independence", 40, |rng| {
        let (m, k, n) = ragged(rng);
        let a = rng.normal_vec(m * k, 1.0);
        let bt = rng.normal_vec(n * k, 1.0);
        let mut full = vec![0.0; m * n];
        mm_bt_into(&a, &bt, m, k, n, &mut full, false);

        // single query row (the cached decode shape: m = 1)
        let qi = rng.range(m);
        let mut row = vec![0.0; n];
        mm_bt_into(&a[qi * k..(qi + 1) * k], &bt, 1, k, n, &mut row, false);
        assert_eq!(bits(&row), bits(&full[qi * n..(qi + 1) * n]), "m = 1 row {} diverged", qi);

        // column prefix (the causal set grows one key at a time)
        let nn = 1 + rng.range(n);
        let mut prefix = vec![0.0; m * nn];
        mm_bt_into(&a, &bt[..nn * k], m, k, nn, &mut prefix, false);
        for i in 0..m {
            assert_eq!(
                bits(&prefix[i * nn..(i + 1) * nn]),
                bits(&full[i * n..i * n + nn]),
                "column prefix {} diverged on row {}",
                nn,
                i
            );
        }
    });
}

/// Masked-softmax invariants: a row with an exact `-inf` tail produces
/// exactly-zero tail weights and leaves the live prefix bitwise identical
/// to softmax over the prefix alone — per build, the property that makes
/// cached rows (length len) match full causal rows (length sk).
#[test]
fn softmax_masked_tail_is_exactly_zero_and_prefix_bitwise() {
    let _g = lock();
    forall("simd-softmax-masked-tail", 60, |rng| {
        let n = 1 + rng.range(40);
        let tail = rng.range(24);
        let logits = rng.normal_vec(n, 3.0);

        let mut prefix = logits.clone();
        softmax_row(&mut prefix);

        let mut padded = logits;
        padded.resize(n + tail, f32::NEG_INFINITY);
        softmax_row(&mut padded);

        let msg = format!("live prefix diverged (n={} tail={})", n, tail);
        assert_eq!(bits(&padded[..n]), bits(&prefix), "{}", msg);
        for (j, &w) in padded[n..].iter().enumerate() {
            assert_eq!(w.to_bits(), 0.0f32.to_bits(), "masked weight {} not exactly +0.0", n + j);
        }
    });
}

#[test]
fn softmax_matches_scalar_within_ulp() {
    let _g = lock();
    forall("simd-softmax-ulp", 60, |rng| {
        let n = 1 + rng.range(40);
        let logits = rng.normal_vec(n, 4.0);
        let mut got = logits.clone();
        let mut want = logits;
        softmax_row(&mut got);
        softmax_row_scalar(&mut want);
        let mut gsum = 0.0f64;
        for (x, y) in got.iter().zip(&want) {
            // weights live in [0, 1]; the polynomial exp is a few-ulp
            // approximation of libm's
            assert!((x - y).abs() <= 1e-5, "softmax weight {x} vs scalar {y} (n={n})");
            gsum += *x as f64;
        }
        assert!((gsum - 1.0).abs() < 1e-4, "softmax row must normalize, got {gsum}");
    });
}

/// LayerNorm + GELU: the dispatched rows must track the force-scalar rows
/// within ulp bounds, and (for LN) row results must not depend on how many
/// rows share one call — the cached single-row path uses the same kernel.
#[test]
fn layer_norm_and_gelu_match_scalar_within_ulp() {
    let _g = lock();
    forall("simd-ln-gelu-ulp", 40, |rng| {
        let d = 1 + rng.range(48);
        let rows = 1 + rng.range(4);
        let x = rng.normal_vec(rows * d, 1.5);
        let g: Vec<f32> = (0..d).map(|_| 1.0 + 0.3 * rng.normal()).collect();
        let b = rng.normal_vec(d, 0.3);

        let mut got = vec![0.0; rows * d];
        layer_norm_fwd_into(&x, &g, &b, d, &mut got);

        // single-row calls must reproduce the multi-row call bitwise
        for r in 0..rows {
            let mut one = vec![0.0; d];
            layer_norm_fwd_into(&x[r * d..(r + 1) * d], &g, &b, d, &mut one);
            assert_eq!(bits(&one), bits(&got[r * d..(r + 1) * d]), "LN row {} shape-dependent", r);
        }

        set_force_scalar(true);
        let mut want = vec![0.0; rows * d];
        layer_norm_fwd_into(&x, &g, &b, d, &mut want);
        set_force_scalar(false);
        for (i, (xv, yv)) in got.iter().zip(&want).enumerate() {
            assert!(
                (xv - yv).abs() <= 1e-4 + 1e-4 * yv.abs(),
                "LN[{i}] = {xv} vs scalar {yv} (d={d})"
            );
        }

        let mut row = rng.normal_vec(d, 2.0);
        let want_gelu: Vec<f32> = row.iter().map(|&v| gelu(v)).collect();
        gelu_row(&mut row);
        for (i, (xv, yv)) in row.iter().zip(&want_gelu).enumerate() {
            assert!(
                (xv - yv).abs() <= 1e-5 * (1.0 + yv.abs()),
                "gelu[{i}] = {xv} vs scalar {yv} (d={d})"
            );
        }
    });
}

/// End-to-end rerun under whatever kernels this build dispatches to: a
/// short `train_step` run stays finite, and cached decode stays bitwise
/// identical to the full-forward loop (the `decode_cache.rs` contract,
/// re-pinned here so `--features simd` CI exercises it with the SIMD
/// kernels dispatched).
#[test]
fn train_step_and_cached_decode_run_under_dispatched_kernels() {
    let _g = lock();

    let mut rc = presets::by_name("mc").expect("mc preset");
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_enc_layers = 4;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.probe_every = 0;
    rc.train.adaptive = false;
    rc.train.warmup = 0;
    let mut s = Session::builder()
        .config(rc)
        .task(Task::Tag)
        .backend(Box::new(Mgrit))
        .build()
        .expect("session");
    for _ in 0..3 {
        let rec = s.train_step();
        assert!(rec.loss.is_finite(), "train_step loss diverged: {}", rec.loss);
    }

    let mut rc = presets::by_name("gpt").expect("gpt preset");
    presets::shrink_for_bench(&mut rc);
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_dec_layers = 6;
    rc.model.buffer_open = 1;
    rc.model.buffer_close = 1;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    let params = ParamStore::init(&rc.model, Init::Default, 5);
    assert_eq!(rc.model.arch, Arch::Decoder);
    let mut inf = InferSession::from_parts(rc, params, Box::new(Mgrit)).expect("infer session");
    inf.set_fwd_iters(None); // serial reference mode, like decode_cache.rs
    let plen = inf.rc.model.seq / 2;
    let prompts: Vec<i32> = (0..inf.rc.model.batch * plen).map(|i| (i % 7) as i32).collect();
    for opts in [
        DecodeOptions::default(),
        DecodeOptions { top_k: 4, temperature: 0.8, seed: 9, max_new: 0 },
    ] {
        let cached = inf.generate(&prompts, plen, &opts).unwrap();
        inf.set_incremental(false);
        let full = inf.generate(&prompts, plen, &opts).unwrap();
        inf.set_incremental(true);
        assert_eq!(
            cached, full,
            "cached decode diverged from the full-forward loop under the dispatched kernels"
        );
    }
}
