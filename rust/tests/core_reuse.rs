//! Acceptance pin for the persistent solve contexts: **no `MgritCore`
//! construction on the steady-state training path** — cores are built at
//! most once per `Session` per direction, plus explicit rebuilds on
//! cf/levels changes.
//!
//! Watches the process-wide `MgritCore::total_constructed()` counter, so
//! this file must stay a single-`#[test]` binary (tests within one binary
//! run concurrently and any other test constructing cores would perturb
//! the count).

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Mgrit, Session, Task};
use layertime::mgrit::MgritCore;

#[test]
fn steady_state_training_constructs_no_cores() {
    let mut rc = presets::by_name("mc").expect("mc preset");
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 2;
    rc.model.n_classes = 4;
    rc.model.n_enc_layers = 8;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true };
    rc.train.probe_every = 0;
    rc.train.adaptive = false;
    let mut s = Session::builder()
        .config(rc)
        .task(Task::Tag)
        .backend(Box::new(Mgrit))
        .build()
        .expect("session");

    assert_eq!(s.solve_core_builds(), 0, "cores are built lazily, not at session build");
    s.train_step();
    assert_eq!(s.solve_core_builds(), 2, "first step builds one core per direction");

    // steady state: training steps and evaluation sweeps construct nothing
    let global = MgritCore::total_constructed();
    for _ in 0..5 {
        s.train_step();
    }
    s.evaluate(2);
    assert_eq!(
        MgritCore::total_constructed(),
        global,
        "steady-state training must not construct MGRIT cores"
    );
    assert_eq!(s.solve_core_builds(), 2);

    // a mid-run cf change is a different grid: exactly one explicit
    // rebuild per direction, then steady again
    s.rc.mgrit.cf = 4;
    s.train_step();
    assert_eq!(s.solve_core_builds(), 4, "cf change rebuilds both directions");
    assert_eq!(MgritCore::total_constructed(), global + 2);
    let global = MgritCore::total_constructed();
    s.train_step();
    assert_eq!(MgritCore::total_constructed(), global, "and the rebuilt cores are cached");
}
