//! End-to-end training integration: short runs of every task on tiny
//! models through the full coordinator (embed → MGRIT → loss → adjoint →
//! optimizer), artifact-free (pure-Rust propagator) so `cargo test` is
//! self-contained.

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Task, TrainRun};
use layertime::model::{Init, ParamStore};

/// Shrink a preset to test scale (tiny width, few layers, few steps).
fn tiny(preset: &str, steps: usize) -> layertime::config::RunConfig {
    let mut rc = presets::by_name(preset).unwrap();
    rc.model.vocab = 16;
    rc.model.d_model = 16;
    rc.model.n_heads = 2;
    rc.model.d_ff = 32;
    rc.model.seq = 8;
    rc.model.batch = 4;
    rc.model.n_classes = 4;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.train.steps = steps;
    rc.train.eval_every = steps;
    rc.train.probe_every = 0; // probes off unless the test wants them
    rc.train.adaptive = false;
    rc.train.warmup = 0;
    rc
}

#[test]
fn tag_task_learns_with_mgrit() {
    let mut rc = tiny("mc", 120);
    rc.model.n_enc_layers = 4;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true };
    rc.train.opt = layertime::config::OptKind::Adam;
    rc.train.lr = 5e-3;
    let mut run = TrainRun::new(rc, Task::Tag, None).unwrap();
    let report = run.train().unwrap();
    let first = report.curve[0].loss;
    let last = report.final_loss;
    assert!(last < first * 0.8, "loss did not drop: {} -> {}", first, last);
    // better than chance (4 classes)
    assert!(report.final_metric > 0.3, "metric {}", report.final_metric);
}

#[test]
fn lm_task_learns_with_buffers() {
    // GPT-like: buffers + serial forward + 1 MGRIT backward iteration
    let mut rc = tiny("gpt", 120);
    rc.model.n_dec_layers = 8;
    rc.model.buffer_open = 2;
    rc.model.buffer_close = 2;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: true };
    rc.train.opt = layertime::config::OptKind::Adam;
    rc.train.lr = 5e-3;
    let mut run = TrainRun::new(rc, Task::Lm, None).unwrap();
    let report = run.train().unwrap();
    assert!(report.final_loss < report.curve[0].loss, "{} -> {}",
        report.curve[0].loss, report.final_loss);
    assert!(report.final_loss.is_finite());
}

#[test]
fn translate_task_runs_encdec() {
    let mut rc = tiny("mt", 80);
    rc.model.n_enc_layers = 3;
    rc.model.n_dec_layers = 3;
    rc.mgrit = MgritConfig { cf: 3, levels: 2, fwd_iters: Some(2), bwd_iters: Some(2), fcf: true };
    rc.train.lr = 5e-3;
    let mut run = TrainRun::new(rc, Task::Translate, None).unwrap();
    let report = run.train().unwrap();
    assert!(report.final_loss < report.curve[0].loss);
    // BLEU is defined and finite
    assert!((0.0..=1.0).contains(&report.final_metric));
}

#[test]
fn cls_task_runs_vit_style() {
    let mut rc = tiny("vit", 30);
    rc.model.seq = 16; // must be square for the image task
    rc.model.n_enc_layers = 4;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: true };
    rc.train.lr = 1e-3;
    let mut run = TrainRun::new(rc, Task::Cls, None).unwrap();
    let report = run.train().unwrap();
    assert!(report.final_loss.is_finite());
    assert!(report.final_loss < report.curve[0].loss * 1.2);
}

#[test]
fn serial_and_converged_mgrit_produce_same_dynamics() {
    // The paper's central accuracy claim at test scale: layer-parallel with
    // enough iterations reproduces serial training step for step.
    let mut rc_serial = tiny("mc", 12);
    rc_serial.model.n_enc_layers = 8;
    rc_serial.mgrit = MgritConfig::serial();
    rc_serial.train.lr = 0.02;
    let mut rc_mg = rc_serial.clone();
    rc_mg.mgrit =
        MgritConfig { cf: 2, levels: 2, fwd_iters: Some(8), bwd_iters: Some(8), fcf: true };

    let mut run_a = TrainRun::new(rc_serial, Task::Tag, None).unwrap();
    run_a.warm_start = false;
    let mut run_b = TrainRun::new(rc_mg, Task::Tag, None).unwrap();
    run_b.warm_start = false;
    let ra = run_a.train().unwrap();
    let rb = run_b.train().unwrap();
    for (a, b) in ra.curve.iter().zip(&rb.curve) {
        assert!(
            (a.loss - b.loss).abs() < 5e-3 * (1.0 + a.loss.abs()),
            "step {}: serial {} vs mgrit {}",
            a.step,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn one_iteration_mgrit_diverges_from_serial_dynamics() {
    // ... and with too few iterations the trajectories drift apart — the
    // inexactness the adaptive controller exists to catch (Fig. 4).
    let mut rc_serial = tiny("mc", 40);
    rc_serial.model.n_enc_layers = 16;
    rc_serial.mgrit = MgritConfig::serial();
    rc_serial.train.opt = layertime::config::OptKind::Adam;
    rc_serial.train.lr = 0.01;
    let mut rc_mg = rc_serial.clone();
    rc_mg.mgrit =
        MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };

    let mut run_a = TrainRun::new(rc_serial, Task::Tag, None).unwrap();
    run_a.warm_start = false;
    let mut run_b = TrainRun::new(rc_mg, Task::Tag, None).unwrap();
    run_b.warm_start = false;
    let ra = run_a.train().unwrap();
    let rb = run_b.train().unwrap();
    let drift: f32 = ra
        .curve
        .iter()
        .zip(&rb.curve)
        .map(|(a, b)| (a.loss - b.loss).abs())
        .fold(0.0, f32::max);
    assert!(drift > 1e-6, "expected visible drift, got {}", drift);
}

#[test]
fn adaptive_probe_records_convergence_factors() {
    let mut rc = tiny("mc", 20);
    rc.model.n_enc_layers = 8;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.adaptive = true;
    rc.train.probe_every = 5;
    let mut run = TrainRun::new(rc, Task::Tag, None).unwrap();
    let report = run.train().unwrap();
    assert!(!report.probes.is_empty(), "no probes recorded");
    for p in &report.probes {
        assert!(p.rho_fwd.is_some() || p.rho_bwd.is_some());
        if let Some(r) = p.rho_fwd {
            assert!(r.is_finite() && r >= 0.0);
        }
    }
}

#[test]
fn dp_microbatching_averages_gradients() {
    let mut rc = tiny("mc", 10);
    rc.model.n_enc_layers = 4;
    rc.dp_degree = 2;
    rc.mgrit = MgritConfig::serial();
    let mut run = TrainRun::new(rc, Task::Tag, None).unwrap();
    let report = run.train().unwrap();
    assert!(report.final_loss.is_finite());
    assert_eq!(report.curve.len(), 10);
}

#[test]
fn finetune_from_checkpoint_preserves_params() {
    let rc = tiny("mc", 5);
    let ps = ParamStore::init(&rc.model, Init::Default, 42);
    let path = std::env::temp_dir().join("layertime_ft_test.bin");
    ps.save(path.to_str().unwrap()).unwrap();
    let loaded = ParamStore::load(&rc.model, path.to_str().unwrap()).unwrap();
    let mut run = TrainRun::from_params(rc, Task::Tag, loaded, None).unwrap();
    let report = run.train().unwrap();
    assert_eq!(report.curve.len(), 5);
    std::fs::remove_file(path).ok();
}
