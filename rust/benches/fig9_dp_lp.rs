//! Figure 9 — combining data- and layer-parallelism under fixed GPU
//! budgets (16/32/64 GPUs, batch scaled with budget): time per batch vs
//! the data-parallel degree. Each curve is convex — too little dp wastes
//! data-parallel efficiency, too much dp makes the gradient allreduce
//! dominate and gives up layer parallelism. 64-layer GPT analogue.

use layertime::parallel::{DeviceModel, SimConfig, Simulator};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, Table};

fn main() {
    let (seq, d, ff) = (1024usize, 768usize, 3072usize);
    let n_layers = 64usize;
    let phi = (8 * seq * d * d + 4 * seq * seq * d + 4 * seq * d * ff) as f64;
    let budgets = [16usize, 32, 64];
    let dps = [1usize, 2, 4, 8, 16, 32, 64];

    println!("Figure 9: time per batch, fixed GPU budget, dp × lp split (64-layer GPT)\n");
    let mut csv = CsvWriter::create("bench_out/fig9_dp_lp.csv",
        &["budget", "dp", "lp", "time_s"]).unwrap();
    let mut tbl = Table::new(&["dp", "16 GPUs (B=16)", "32 GPUs (B=32)", "64 GPUs (B=64)"]);
    let mut rows: Vec<Vec<String>> = dps.iter().map(|&dp| vec![dp.to_string()]).collect();
    let mut minima = vec![(f64::INFINITY, 0usize); budgets.len()];
    for (bi, &budget) in budgets.iter().enumerate() {
        for (ri, &dp) in dps.iter().enumerate() {
            if dp > budget {
                rows[ri].push("-".into());
                continue;
            }
            let lp = budget / dp;
            let sim = Simulator::new(SimConfig {
                n_layers,
                cf: 4,
                levels: 2,
                fwd_iters: Some(1),
                bwd_iters: Some(1),
                fcf: true,
                lp,
                dp,
                flops_per_sample_step: phi,
                batch: budget, // batch scales with the budget (paper setup)
                state_bytes: (seq * d * 4) as f64,
                param_bytes: (n_layers * (4 * d * d + 2 * d * ff)) as f64 * 4.0,
                device: DeviceModel::a100(),
            });
            let t = sim.batch_time().total;
            if t < minima[bi].0 {
                minima[bi] = (t, dp);
            }
            rows[ri].push(f(t, 4));
            csv.row(&[budget.to_string(), dp.to_string(), lp.to_string(), t.to_string()])
                .unwrap();
        }
    }
    for r in rows {
        tbl.row(r);
    }
    tbl.print();
    csv.flush().unwrap();
    for (bi, &budget) in budgets.iter().enumerate() {
        println!("optimum for {} GPUs: dp={} (lp={})", budget, minima[bi].1, budget / minima[bi].1);
    }
    println!("\nseries written to bench_out/fig9_dp_lp.csv");
    println!("paper shape check: each curve is convex with an interior optimum —");
    println!("layer-parallelism adds speedup beyond pure data-parallel.");
}
