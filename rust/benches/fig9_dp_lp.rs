//! Figure 9 — combining data- and layer-parallelism under fixed GPU
//! budgets (16/32/64 GPUs, batch scaled with budget): time per batch vs
//! the data-parallel degree. Each curve is convex — too little dp wastes
//! data-parallel efficiency, too much dp makes the gradient allreduce
//! dominate and gives up layer parallelism. 64-layer GPT analogue.
//!
//! A second section grounds the model on this testbed: every worker split
//! of a small real training config is **executed** (concurrent replica
//! lanes × threaded relaxation workers) and timed next to the simulator's
//! prediction from a measured-Φ calibration — the measured-vs-simulated
//! column is the model error behind the `--workers` auto-split heuristic.

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Session, Task};
use layertime::ode::{shared_params, Propagator, RustPropagator};
use layertime::parallel::{worker_splits, DeviceModel, SimConfig, Simulator};
use layertime::tensor::Tensor;
use layertime::util::bench::BenchRunner;
use layertime::util::csv::CsvWriter;
use layertime::util::rng::Rng;
use layertime::util::table::{f, Table};

fn main() {
    let (seq, d, ff) = (1024usize, 768usize, 3072usize);
    let n_layers = 64usize;
    let phi = (8 * seq * d * d + 4 * seq * seq * d + 4 * seq * d * ff) as f64;
    let budgets = [16usize, 32, 64];
    let dps = [1usize, 2, 4, 8, 16, 32, 64];

    println!("Figure 9: time per batch, fixed GPU budget, dp × lp split (64-layer GPT)\n");
    let mut csv = CsvWriter::create("bench_out/fig9_dp_lp.csv",
        &["budget", "dp", "lp", "time_s"]).unwrap();
    let mut tbl = Table::new(&["dp", "16 GPUs (B=16)", "32 GPUs (B=32)", "64 GPUs (B=64)"]);
    let mut rows: Vec<Vec<String>> = dps.iter().map(|&dp| vec![dp.to_string()]).collect();
    let mut minima = vec![(f64::INFINITY, 0usize); budgets.len()];
    for (bi, &budget) in budgets.iter().enumerate() {
        for (ri, &dp) in dps.iter().enumerate() {
            if dp > budget {
                rows[ri].push("-".into());
                continue;
            }
            let lp = budget / dp;
            let sim = Simulator::new(SimConfig {
                n_layers,
                cf: 4,
                levels: 2,
                fwd_iters: Some(1),
                bwd_iters: Some(1),
                fcf: true,
                lp,
                dp,
                flops_per_sample_step: phi,
                batch: budget, // batch scales with the budget (paper setup)
                state_bytes: (seq * d * 4) as f64,
                param_bytes: (n_layers * (4 * d * d + 2 * d * ff)) as f64 * 4.0,
                device: DeviceModel::a100(),
            });
            let t = sim.batch_time().total;
            if t < minima[bi].0 {
                minima[bi] = (t, dp);
            }
            rows[ri].push(f(t, 4));
            csv.row(&[budget.to_string(), dp.to_string(), lp.to_string(), t.to_string()])
                .unwrap();
        }
    }
    for r in rows {
        tbl.row(r);
    }
    tbl.print();
    csv.flush().unwrap();
    for (bi, &budget) in budgets.iter().enumerate() {
        println!("optimum for {} GPUs: dp={} (lp={})", budget, minima[bi].1, budget / minima[bi].1);
    }
    println!("\nseries written to bench_out/fig9_dp_lp.csv");
    println!("paper shape check: each curve is convex with an interior optimum —");
    println!("layer-parallelism adds speedup beyond pure data-parallel.");

    // --- measured vs simulated on this testbed -------------------------------
    // Every worker split of a small real config is executed (dp replica
    // lanes × lp relaxation workers, the same machinery `--dp-workers`
    // drives) and timed next to the simulator's prediction from a
    // measured-Φ calibration. The error column is the model error behind
    // the auto-split heuristic; the simulator omits the optimizer and
    // loss-head cost, so a steady positive bias is expected — what matters
    // for the split choice is the *relative* ordering across splits.
    println!("\nMeasured vs simulated batch time (tiny 8-layer config, this machine)\n");
    let mut rc = presets::mc_tiny();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 8;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.adaptive = false;
    rc.train.probe_every = 0;
    rc.dp_degree = 4;
    let m = rc.model.clone();

    // calibrate: per-sample Φ time on this shape (one layer step over the
    // full batch, divided by batch) — the simulator's device-model input
    let mut rng = Rng::new(17);
    let params = shared_params(vec![rng.normal_vec(m.p_enc(), 0.02); 1]);
    let prop = RustPropagator::new(&m, 1.0, params);
    let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    let mut out = Tensor::zeros(&prop.state_shape());
    let runner = BenchRunner::new(2, 10);
    let phi_st = runner.report("Φ calibration (one layer step, full batch)", || {
        prop.step_into(0, 1.0, &z, &mut out)
    });
    let phi_per_sample = phi_st.mean / m.batch as f64;
    let flops_per_sample = 12.0 * (m.seq * m.d_model * m.d_model) as f64
        + 4.0 * (m.seq * m.seq * m.d_model) as f64
        + 4.0 * (m.seq * m.d_model * m.d_ff) as f64;

    let mut csv2 = CsvWriter::create(
        "bench_out/fig9_dp_lp_measured.csv",
        &["workers", "dp_lanes", "lp", "measured_s", "simulated_s", "model_error_pct"],
    )
    .unwrap();
    let mut tbl2 =
        Table::new(&["workers", "dp lanes", "lp", "measured s", "simulated s", "error %"]);
    for workers in [1usize, 2, 4] {
        for t in worker_splits(workers, rc.dp_degree) {
            let sim = Simulator::new(SimConfig {
                n_layers: m.parallel_layers().max(1),
                cf: rc.mgrit.cf,
                levels: rc.mgrit.levels,
                fwd_iters: rc.mgrit.fwd_iters,
                bwd_iters: rc.mgrit.bwd_iters,
                fcf: rc.mgrit.fcf,
                lp: t.lp,
                dp: t.dp,
                flops_per_sample_step: flops_per_sample,
                batch: m.batch * rc.dp_degree,
                state_bytes: (m.seq * m.d_model * 4) as f64,
                param_bytes: (m.total_layers() * m.p_enc() * 4) as f64,
                device: DeviceModel::cpu_measured(phi_per_sample, flops_per_sample),
            });
            let simulated = sim.batch_time().total;
            let mut run = Session::builder()
                .config(rc.clone())
                .task(Task::Tag)
                .workers(workers)
                .dp_workers(t.dp)
                .build()
                .unwrap();
            run.train_step(); // cores, pools, and fabric built outside the timing
            let st = runner.report(
                &format!("train step (workers {}, dp lanes {}, lp {})", workers, t.dp, t.lp),
                || run.train_step(),
            );
            let err = 100.0 * (st.mean - simulated) / simulated.max(1e-12);
            tbl2.row(vec![
                workers.to_string(),
                t.dp.to_string(),
                t.lp.to_string(),
                f(st.mean, 5),
                f(simulated, 5),
                f(err, 1),
            ]);
            csv2.row(&[
                workers.to_string(),
                t.dp.to_string(),
                t.lp.to_string(),
                st.mean.to_string(),
                simulated.to_string(),
                err.to_string(),
            ])
            .unwrap();
        }
    }
    tbl2.print();
    csv2.flush().unwrap();
    println!("\nmeasured-vs-simulated series written to bench_out/fig9_dp_lp_measured.csv");
}
