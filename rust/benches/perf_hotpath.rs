//! §Perf micro-benchmarks of the training hot path (EXPERIMENTS.md §Perf):
//!   kernel layer      — scalar vs dispatched-SIMD rows per kernel
//!                       (mm / mm_at / mm_bt / softmax / layernorm); the
//!                       "(simd)" rows appear only in builds that actually
//!                       dispatch vector kernels (`--features simd` on an
//!                       AVX2 or NEON host)
//!   Φ latency         — XLA/PJRT (Pallas) vs pure-Rust reference
//!   Φ-VJP latency     — same, backward
//!   buffer reuse      — step_into/adjoint_step_into vs allocating step
//!   marshalling       — Tensor⇄Literal overhead per call
//!   MGRIT V-cycle     — engine overhead on a trivial Φ (pure coordinator)
//!   full train step   — tiny end-to-end batch (Rust Φ)
//!
//!   threaded sweeps   — staged (slab-copy + stitch) vs in-place shared-grid
//!                       relaxation on the persistent worker pool, and
//!                       full-solve / train-step scaling over worker counts
//!   batched decode    — InferSession autoregressive decode throughput
//!                       (tokens/sec) across batch 1/8/32, serial vs MGRIT
//!                       forward solves on the cached hierarchy, plus the
//!                       incremental KV-cached path (short prefill-bound
//!                       vs long steady-state generations)
//!
//! Flags:
//!   --json        write machine-readable results to BENCH_hotpath.json
//!                 (ns/op per row) so the perf trajectory is tracked across PRs
//!   --fast        1 warmup + 5 samples per row, reduced kernel shape list
//!                 (CI smoke mode — keeps the whole run under a minute)
//!   --workers N   add worker count N to the threaded scaling sweep
//!                 (default sweep: 1, 2, 4)
//!
//! Uses artifacts when present (`make artifacts`), otherwise skips the XLA
//! rows.

use std::sync::Arc;

use layertime::config::{presets, Arch, MgritConfig};
use layertime::coordinator::{Mgrit, Task, TrainRun};
use layertime::infer::{DecodeOptions, InferSession};
use layertime::mgrit::MgritSolver;
use layertime::model::{Init, ParamStore};
use layertime::ode::{shared_params, LinearOde, Propagator, RustPropagator, XlaPropagator};
use layertime::parallel::{exec, WorkerPool};
use layertime::runtime::{Value, XlaEngine};
use layertime::reference::layer_norm_fwd_into;
use layertime::serve::{drive_load, GenerateRequest, ServeLoop};
use layertime::tensor::{
    mm_at_into, mm_bt_into, mm_into, set_force_scalar, simd_active, softmax_row, Tensor,
};
use layertime::util::bench::{BenchLog, BenchRunner, Stats};
use layertime::util::rng::Rng;

/// Time a row and record it in the JSON log under the same label.
fn timed<T, F: FnMut() -> T>(
    runner: &BenchRunner,
    log: &mut BenchLog,
    label: &str,
    f: F,
) -> Stats {
    let st = runner.report(label, f);
    log.push(label, st);
    st
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let json_out = args.iter().any(|a| a == "--json");
    let fast = args.iter().any(|a| a == "--fast");
    let runner = if fast { BenchRunner::new(1, 5) } else { BenchRunner::new(3, 15) };
    let mut log = BenchLog::new();
    println!("perf_hotpath — coordinator + runtime micro-benchmarks\n");

    // --- MGRIT engine overhead on a free Φ --------------------------------
    let mut rng = Rng::new(0);
    let ode = LinearOde::random_stable(&mut rng, 8, 64, 0.05);
    let z0 = Tensor::randn(&mut rng, &[8, 1], 1.0);
    let solver = MgritSolver::new(
        &ode,
        MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true },
    );
    timed(&runner, &mut log, "mgrit v-cycle (64 steps, trivial Φ)", || {
        solver.forward(&z0, Some(1), None, false)
    });
    timed(&runner, &mut log, "mgrit serial solve (64 steps)", || {
        solver.forward(&z0, None, None, false)
    });

    // --- kernel layer: scalar vs dispatched SIMD ------------------------------
    // One row per kernel, shape, and mode: "(scalar)" forces the always-
    // scalar kernels through the runtime kill switch; "(simd)" rows appear
    // only when this build actually dispatches vector kernels (`--features
    // simd` on an AVX2/NEON host), so the gap within a pair is the measured
    // per-kernel SIMD speedup. `--fast` trims the shape list so the CI
    // bench-smoke run stays under a minute.
    {
        // (m, k, n): square-ish GEMM, ragged tails, and the cached-decode
        // single-query-row shape
        let shapes: &[(usize, usize, usize)] = if fast {
            &[(64, 64, 128), (1, 64, 256)]
        } else {
            &[(256, 64, 256), (64, 64, 192), (33, 48, 80), (1, 64, 512)]
        };
        let modes: &[(&str, bool)] =
            if simd_active() { &[("scalar", true), ("simd", false)] } else { &[("scalar", true)] };
        let mut rng = Rng::new(3);
        for &(m, k, n) in shapes {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let bt = rng.normal_vec(n * k, 1.0);
            let at: Vec<f32> = (0..k * m).map(|i| a[(i % m) * k + i / m]).collect();
            let mut out = vec![0.0; m * n];
            for &(tag, force) in modes {
                set_force_scalar(force);
                timed(&runner, &mut log, &format!("mm    {}x{}x{} ({})", m, k, n, tag), || {
                    mm_into(&a, &b, m, k, n, &mut out, false)
                });
                timed(&runner, &mut log, &format!("mm_at {}x{}x{} ({})", m, k, n, tag), || {
                    mm_at_into(&at, &b, k, m, n, &mut out, false)
                });
                timed(&runner, &mut log, &format!("mm_bt {}x{}x{} ({})", m, k, n, tag), || {
                    mm_bt_into(&a, &bt, m, k, n, &mut out, false)
                });
            }
        }
        // row-wise kernels at a transformer-ish width
        let d = if fast { 128 } else { 256 };
        let rows = 64;
        let x = rng.normal_vec(rows * d, 1.0);
        let gain = rng.normal_vec(d, 0.2);
        let bias = rng.normal_vec(d, 0.2);
        let mut out = vec![0.0; rows * d];
        for &(tag, force) in modes {
            set_force_scalar(force);
            timed(&runner, &mut log, &format!("softmax {}x{} ({})", rows, d, tag), || {
                out.copy_from_slice(&x);
                for r in out.chunks_exact_mut(d) {
                    softmax_row(r);
                }
            });
            timed(&runner, &mut log, &format!("layernorm {}x{} ({})", rows, d, tag), || {
                layer_norm_fwd_into(&x, &gain, &bias, d, &mut out)
            });
        }
        set_force_scalar(false);
    }

    // --- rust reference Φ ---------------------------------------------------
    let mut model = presets::mc_tiny().model;
    model.vocab = 64;
    model.d_model = 64;
    model.n_heads = 4;
    model.d_ff = 128;
    model.seq = 32;
    model.batch = 8;
    model.arch = Arch::Encoder;
    let params = shared_params(vec![rng.normal_vec(model.p_enc(), 0.02); 1]);
    let rust_prop = RustPropagator::new(&model, 1.0, params.clone());
    let z = Tensor::randn(&mut rng, &rust_prop.state_shape(), 1.0);
    let ct = Tensor::randn(&mut rng, &rust_prop.state_shape(), 1.0);
    timed(&runner, &mut log, "Φ fwd  (rust reference, d=64 s=32 b=8)", || {
        rust_prop.step(0, 1.0, &z)
    });
    timed(&runner, &mut log, "Φ vjp  (rust reference)", || {
        rust_prop.adjoint_step(0, 1.0, &z, &ct)
    });
    // buffer-reusing entry points (the MGRIT sweep path): same math, zero
    // steady-state allocations
    let mut out = Tensor::zeros(&rust_prop.state_shape());
    timed(&runner, &mut log, "Φ fwd  (step_into, reused buffers)", || {
        rust_prop.step_into(0, 1.0, &z, &mut out)
    });
    timed(&runner, &mut log, "Φ vjp  (adjoint_step_into)", || {
        rust_prop.adjoint_step_into(0, 1.0, &z, &ct, &mut out)
    });
    // SIMD builds: the same Φ through the forced-scalar kernels, so the gap
    // to the rows above is the end-to-end SIMD speedup on one layer step
    if simd_active() {
        set_force_scalar(true);
        timed(&runner, &mut log, "Φ fwd  (step_into, forced scalar)", || {
            rust_prop.step_into(0, 1.0, &z, &mut out)
        });
        timed(&runner, &mut log, "Φ vjp  (adjoint_step_into, forced scalar)", || {
            rust_prop.adjoint_step_into(0, 1.0, &z, &ct, &mut out)
        });
        set_force_scalar(false);
    }

    // --- XLA Φ (artifacts) --------------------------------------------------
    let dir = std::env::var("LAYERTIME_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        let engine = Arc::new(XlaEngine::load(&dir)?);
        engine.warmup()?;
        let xla_prop = XlaPropagator::new(engine.clone(), &model, 1.0, params.clone())?;
        timed(&runner, &mut log, "Φ fwd  (XLA/PJRT, Pallas kernels)", || {
            xla_prop.step(0, 1.0, &z)
        });
        timed(&runner, &mut log, "Φ vjp  (XLA/PJRT)", || xla_prop.adjoint_step(0, 1.0, &z, &ct));

        // L1 ablation: the same Φ lowered from the pure-jnp reference
        // (no Pallas) — quantifies the interpret-mode overhead on CPU.
        let ref_dir =
            std::env::var("LAYERTIME_ARTIFACTS_REF").unwrap_or_else(|_| "artifacts_ref".into());
        if std::path::Path::new(&ref_dir).join("manifest.json").exists() {
            let engine_ref = Arc::new(XlaEngine::load(&ref_dir)?);
            engine_ref.warmup()?;
            let prop_ref = XlaPropagator::new(engine_ref, &model, 1.0, params.clone())?;
            timed(&runner, &mut log, "Φ fwd  (XLA/PJRT, pure-jnp lowering)", || {
                prop_ref.step(0, 1.0, &z)
            });
            timed(&runner, &mut log, "Φ vjp  (XLA/PJRT, pure-jnp lowering)", || {
                prop_ref.adjoint_step(0, 1.0, &z, &ct)
            });
        }

        // marshalling: executable with pre-built args vs building args
        let exe = engine.executable("enc_step")?;
        let th = {
            let p = params.read().unwrap();
            Tensor::from_vec(p[0].clone(), &[p[0].len()])
        };
        let args_v = vec![Value::F32(z.clone()), Value::F32(th), Value::scalar(1.0)];
        timed(&runner, &mut log, "enc_step call (prebuilt args)", || exe.call(&args_v).unwrap());

        // MGRIT forward over XLA Φ, 8 layers
        let params8 = shared_params(vec![rng.normal_vec(model.p_enc(), 0.02); 8]);
        let prop8 = XlaPropagator::new(engine.clone(), &model, 1.0, params8)?;
        let s8 = MgritSolver::new(
            &prop8,
            MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true },
        );
        let z8 = Tensor::randn(&mut rng, &prop8.state_shape(), 1.0);
        let st = timed(&runner, &mut log, "mgrit fwd solve (8 XLA layers, 1 iter)", || {
            s8.forward(&z8, Some(1), None, false)
        });
        let serial_st = timed(&runner, &mut log, "serial fwd (8 XLA layers)", || {
            s8.forward(&z8, None, None, false)
        });
        let (_, stats) = s8.forward(&z8, Some(1), None, false);
        println!(
            "  -> mgrit Φ-evals/iter = {} (serial = 8); overhead ratio {:.2}x compute,",
            stats.phi_evals,
            st.mean / serial_st.mean
        );
        println!("     exposed parallelism = 2 chunks (see fig6 for modeled wall-clock)");
    } else {
        println!("  (artifacts not built — XLA rows skipped; run `make artifacts`)");
    }

    // --- full train step ------------------------------------------------------
    let mut rc = presets::mc_tiny();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 8;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.adaptive = false;
    let mut run = TrainRun::new(rc.clone(), Task::Tag, None)?;
    timed(&runner, &mut log, "full train step (8 layers, tiny, rust Φ)", || run.train_step());
    if simd_active() {
        set_force_scalar(true);
        let mut run_scalar = TrainRun::new(rc.clone(), Task::Tag, None)?;
        timed(&runner, &mut log, "full train step (forced scalar)", || run_scalar.train_step());
        set_force_scalar(false);
    }

    // --- persistent solve contexts: cached vs fresh hierarchies --------------
    // "cached ctx" is the steady-state path (cores + workspace reused across
    // steps); "fresh ctx" drops the cached hierarchies before every step,
    // i.e. the pre-context behavior of one MgritCore::new per solve. The
    // gap between the two rows is what hierarchy caching buys per step.
    let mut run_cached = TrainRun::new(rc.clone(), Task::Tag, None)?;
    run_cached.train_step(); // build both cores once, outside the timing
    timed(&runner, &mut log, "full train step (cached ctx)", || run_cached.train_step());
    let mut run_fresh = TrainRun::new(rc.clone(), Task::Tag, None)?;
    timed(&runner, &mut log, "full train step (fresh ctx)", || {
        run_fresh.invalidate_solve_context();
        run_fresh.train_step()
    });

    // --- threaded sweeps: staged vs in-place, workers scaling ----------------
    // "staged" is the previous executor (per-sweep slab copies + stitch,
    // boxed-job dispatch); "in-place" is the zero-copy shared-grid path the
    // ThreadedMgrit backend runs on. The gap between the paired rows is what
    // the zero-copy refactor buys per FCF sweep; the cross-worker rows record
    // the layer-parallel scaling trajectory in BENCH_hotpath.json.
    let mut worker_sweep = vec![1usize, 2, 4];
    if let Some(i) = args.iter().position(|a| a == "--workers") {
        if let Some(n) = args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
            if !worker_sweep.contains(&n) {
                worker_sweep.push(n);
            }
        }
    }
    {
        // a relaxation-shaped workload: 64 points of [64, 8] states with a
        // cheap Φ, so the rows measure executor overhead (copies, dispatch,
        // halo traffic), not kernel time
        let (n_pts, cf) = (64usize, 4usize);
        let mut rng = Rng::new(42);
        let proto: Vec<Tensor> =
            (0..=n_pts).map(|_| Tensor::randn(&mut rng, &[64, 8], 1.0)).collect();
        let bias = Tensor::randn(&mut rng, &[64, 8], 0.1);
        let sweep_step = |_l: usize, z: &Tensor, out: &mut Tensor| {
            out.copy_from(z);
            out.scale(0.95);
            out.axpy(0.01, &bias);
        };
        for &wk in &worker_sweep {
            let pool = WorkerPool::new(wk);
            let mut w_staged = proto.clone();
            timed(
                &runner,
                &mut log,
                &format!("threaded FCF sweep (staged, {} workers)", wk),
                || {
                    w_staged = exec::pool_fc_relax(
                        &pool,
                        std::mem::take(&mut w_staged),
                        None,
                        cf,
                        sweep_step,
                    );
                },
            );
            let mut w_inplace = proto.clone();
            timed(
                &runner,
                &mut log,
                &format!("threaded FCF sweep (in-place, {} workers)", wk),
                || exec::pool_fc_relax_mut(&pool, &mut w_inplace, None, cf, sweep_step),
            );
        }
    }
    // full forward MGRIT solves and train steps across the worker sweep
    // (ThreadedMgrit backend: in-place sweeps on the persistent pool)
    {
        let mut rng = Rng::new(7);
        let ode = LinearOde::random_stable(&mut rng, 32, 64, 0.05);
        let z64 = Tensor::randn(&mut rng, &[32, 1], 1.0);
        let cfg64 =
            MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
        for &wk in &worker_sweep {
            let pool = if wk > 1 { Some(std::sync::Arc::new(WorkerPool::new(wk))) } else { None };
            let solver = MgritSolver::with_workers(&ode, cfg64.clone(), wk).pooled(pool);
            let mut core = solver.build_core();
            timed(
                &runner,
                &mut log,
                &format!("threaded fwd solve (64 steps, {} workers, in-place)", wk),
                || solver.forward_with(&mut core, &z64, Some(1), None, false),
            );
        }
        for &wk in &worker_sweep {
            let mut run_wk = layertime::coordinator::Session::builder()
                .config(rc.clone())
                .task(Task::Tag)
                .workers(wk)
                .build()?;
            run_wk.train_step(); // build cores + pool outside the timing
            timed(
                &runner,
                &mut log,
                &format!("full train step ({} workers)", wk),
                || run_wk.train_step(),
            );
        }
    }

    // --- dp×lp composed topology: full train step across the grid ------------
    // Real data parallelism: `dp` replica lanes run concurrently on the dp
    // scheduler pool, each driving an `lp`-worker relaxation pool, gradients
    // reduced through the fabric in the pinned ascending order. Every cell
    // trains bitwise identically (dp_parity.rs); these rows record how
    // wall-clock moves across the composed grid — the measured counterpart
    // of fig9's simulated convex dp-vs-lp tradeoff. Global batch scales
    // with dp (each replica samples its own micro-batch), so same-dp rows
    // are directly comparable and cross-dp rows show the weak-scaling cost.
    {
        for &dp in &[1usize, 2, 4] {
            for &lp in &[1usize, 2, 4] {
                let mut grc = rc.clone();
                grc.dp_degree = dp;
                let mut run_g = layertime::coordinator::Session::builder()
                    .config(grc)
                    .task(Task::Tag)
                    .workers(dp * lp)
                    .dp_workers(dp)
                    .build()?;
                run_g.train_step(); // build cores, pools, and fabric outside the timing
                timed(
                    &runner,
                    &mut log,
                    &format!("full train step dp×lp (dp {}, lp {})", dp, lp),
                    || run_g.train_step(),
                );
            }
        }
    }

    // --- batched decode throughput -------------------------------------------
    // One row = one full `generate` call on a decoder LM (8 layers, 1+1
    // buffers): seq/2 prompt positions, seq/2 generated positions, each
    // needing a full forward (incremental decode is forced OFF here so the
    // rows keep measuring the historical per-token full-forward loop).
    // "serial" is the exact propagation baseline; "mgrit" runs 1 V-cycle
    // per step on the cached hierarchy (the deep-stack acceleration path).
    // tokens/sec = batch · generated / time.
    {
        let mut rc = presets::gpt_small();
        presets::shrink_for_bench(&mut rc);
        rc.model.n_dec_layers = 8;
        rc.model.buffer_open = 1;
        rc.model.buffer_close = 1;
        let gen_positions = rc.model.seq / 2;
        for &batch in &[1usize, 8, 32] {
            for mgrit_fwd in [false, true] {
                let mut vrc = rc.clone();
                vrc.model.batch = batch;
                let fwd = if mgrit_fwd { Some(1) } else { None };
                vrc.mgrit =
                    MgritConfig { cf: 2, levels: 2, fwd_iters: fwd, bwd_iters: Some(1), fcf: true };
                let params = ParamStore::init(&vrc.model, Init::Default, 0);
                let seq = vrc.model.seq;
                let mut inf = InferSession::from_parts(vrc, params, Box::new(Mgrit))?;
                inf.set_incremental(false);
                let plen = seq - gen_positions;
                let prompts: Vec<i32> = vec![1; batch * plen];
                let opts = DecodeOptions::default();
                let mut out = Vec::new();
                inf.generate_into(&prompts, plen, &opts, &mut out)?; // warm core + scratch
                let label = format!(
                    "batched decode ({} tok/call, batch {}, {})",
                    batch * gen_positions,
                    batch,
                    if mgrit_fwd { "mgrit fwd" } else { "serial fwd" }
                );
                let st = timed(&runner, &mut log, &label, || {
                    inf.generate_into(&prompts, plen, &opts, &mut out).unwrap()
                });
                println!(
                    "  -> {:.0} tokens/sec",
                    (batch * gen_positions) as f64 / st.mean.max(1e-12)
                );
            }
        }
    }

    // --- incremental KV-cached decode ----------------------------------------
    // The same decoder LM through the default decode path: one serial
    // prefill forward, then one O(1) cached Φ sweep per token. "short"
    // rows (2 generated positions) are prefill-dominated; "long" rows
    // (seq/2 positions) approach the steady-state per-token cost, so the
    // long-row gap to the serial-fwd rows above is what the cache buys.
    {
        let mut rc = presets::gpt_small();
        presets::shrink_for_bench(&mut rc);
        rc.model.n_dec_layers = 8;
        rc.model.buffer_open = 1;
        rc.model.buffer_close = 1;
        rc.mgrit =
            MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: true };
        let seq = rc.model.seq;
        let plen = seq / 2;
        for &batch in &[1usize, 8, 32] {
            let mut vrc = rc.clone();
            vrc.model.batch = batch;
            let params = ParamStore::init(&vrc.model, Init::Default, 0);
            let mut inf = InferSession::from_parts(vrc, params, Box::new(Mgrit))?;
            let prompts: Vec<i32> = vec![1; batch * plen];
            let mut out = Vec::new();
            for &(tag, max_new) in &[("short", 2usize), ("long", seq - plen)] {
                let opts = DecodeOptions { max_new, ..DecodeOptions::default() };
                inf.generate_into(&prompts, plen, &opts, &mut out)?; // warm cache + scratch
                let label =
                    format!("cached decode ({} new tok, batch {}, {})", max_new, batch, tag);
                let st = timed(&runner, &mut log, &label, || {
                    inf.generate_into(&prompts, plen, &opts, &mut out).unwrap()
                });
                println!(
                    "  -> {:.0} tokens/sec",
                    (batch * max_new) as f64 / st.mean.max(1e-12)
                );
                // SIMD builds: the same generation through the forced-scalar
                // kernels — cached decode is the latency-critical consumer of
                // the m = 1 kernel shapes, so track it under both modes
                if simd_active() {
                    set_force_scalar(true);
                    let label = format!(
                        "cached decode ({} new tok, batch {}, {}, forced scalar)",
                        max_new, batch, tag
                    );
                    timed(&runner, &mut log, &label, || {
                        inf.generate_into(&prompts, plen, &opts, &mut out).unwrap()
                    });
                    set_force_scalar(false);
                }
            }
        }
    }

    // --- serve scheduler occupancy sweep -------------------------------------
    // Continuous-batching throughput on the same decoder LM as the batched-
    // decode rows: a closed-loop driver keeps `occ` requests in flight
    // (active + queued) through the bounded queue, with ragged prompt
    // lengths so joins and retirements interleave. Every request generates
    // exactly 4 tokens, so tokens/sec = requests · 4 / time; the loop runs
    // the default incremental KV-cached decode (joins prefill, everything
    // else is one cached sweep per token), so the gap to the cached-decode
    // rows at the same effective batch is pure scheduler overhead
    // (admission, per-slot sampling, metrics).
    {
        let mut rc = presets::gpt_small();
        presets::shrink_for_bench(&mut rc);
        rc.model.n_dec_layers = 8;
        rc.model.buffer_open = 1;
        rc.model.buffer_close = 1;
        rc.model.batch = rc.model.batch.max(8);
        rc.mgrit =
            MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
        let (b, seq, vocab) = (rc.model.batch, rc.model.seq, rc.model.vocab);
        let params = ParamStore::init(&rc.model, Init::Default, 0);
        let inf = InferSession::from_parts(rc, params, Box::new(Mgrit))?;
        let mut srv = ServeLoop::new(inf, 2 * b)?;
        let max_new = 4usize;
        let mut req_rng = Rng::new(0xBE7C);
        let mut next_id = 0u64;
        let mut make_batch = move |count: usize| -> Vec<GenerateRequest> {
            (0..count)
                .map(|_| {
                    next_id += 1;
                    let plen = 1 + req_rng.range(seq / 2);
                    let prompt = (0..plen).map(|_| req_rng.range(vocab) as i32).collect();
                    GenerateRequest {
                        id: next_id,
                        prompt,
                        max_new,
                        top_k: 8,
                        temperature: 0.9,
                        seed: next_id,
                        deadline_ms: 0,
                    }
                })
                .collect()
        };
        let mut completed = Vec::new();
        // warm the cached hierarchy + scratch outside the timings
        drive_load(&mut srv, &make_batch(b), b, &mut completed)?;
        for &occ in &[1usize, b / 2, b] {
            let work = 2 * b;
            completed.clear();
            completed.reserve(work);
            let label = format!("serve sweep (occupancy {}, batch {})", occ, b);
            let st = timed(&runner, &mut log, &label, || {
                let reqs = make_batch(work);
                completed.clear();
                drive_load(&mut srv, &reqs, occ, &mut completed).unwrap();
                completed.len()
            });
            println!(
                "  -> {:.0} tokens/sec at mean occupancy {:.2}",
                (work * max_new) as f64 / st.mean.max(1e-12),
                srv.metrics.mean_occupancy()
            );
        }
    }

    if json_out {
        let path = "BENCH_hotpath.json";
        log.write(path)?;
        println!("\nwrote {}", path);
    }

    Ok(())
}

// NOTE: run with LAYERTIME_ARTIFACTS_REF=artifacts_ref to also compare the
// Pallas-kernel artifacts against the pure-jnp lowering (L1 ablation).
