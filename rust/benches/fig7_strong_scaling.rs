//! Figure 7 — strong scaling of the encoder-decoder MT task with depth
//! N_enc+N_dec ∈ {80, 160, 320}, MGRIT cf=4, L=2, 2 fwd + 1 bwd iterations
//! (paper: Jean-Zay V100s). Time per batch vs #devices; deeper models keep
//! scaling further — the paper's headline strong-scaling figure.

use layertime::parallel::{DeviceModel, SimConfig, Simulator};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, i, Table};

fn main() {
    let (seq, d, ff, batch) = (274usize, 512usize, 2048usize, 8usize);
    let phi = (8 * seq * d * d + 4 * seq * seq * d + 4 * seq * d * ff) as f64
        + (4 * seq * d * d + 2 * seq * seq * d) as f64; // + cross-attention
    let depths = [80usize, 160, 320];
    let devices = [1usize, 2, 4, 8, 16, 32, 64];

    println!("Figure 7: MT strong scaling (cf=4, L=2, 2 fwd + 1 bwd, V100)\n");
    let mut csv = CsvWriter::create("bench_out/fig7_strong_scaling.csv",
        &["layers", "devices", "time_s", "speedup"]).unwrap();
    let mut tbl = Table::new(&["devices", "80 layers", "160 layers", "320 layers"]);
    let mut rows: Vec<Vec<String>> = devices.iter().map(|&p| vec![p.to_string()]).collect();
    for &n in &depths {
        for (ri, &p) in devices.iter().enumerate() {
            let sim = Simulator::new(SimConfig {
                n_layers: n,
                cf: 4,
                levels: 2,
                fwd_iters: Some(2),
                bwd_iters: Some(1),
                fcf: true,
                lp: p,
                dp: 1,
                flops_per_sample_step: phi,
                batch,
                state_bytes: (2 * seq * d * 4) as f64, // stacked [X, Y]
                param_bytes: (n * (8 * d * d + 2 * d * ff)) as f64 * 4.0,
                device: DeviceModel::v100(),
            });
            let time = sim.batch_time().total;
            rows[ri].push(f(time, 4));
            csv.row(&[n.to_string(), p.to_string(), time.to_string(),
                      sim.speedup_vs_serial().to_string()]).unwrap();
        }
    }
    for r in rows {
        tbl.row(r);
    }
    tbl.print();
    csv.flush().unwrap();
    println!("\nseries written to bench_out/fig7_strong_scaling.csv");
    println!("paper shape check: all depths speed up; the 320-layer model keeps");
    println!("scaling to more devices than the 80-layer one.");
}
