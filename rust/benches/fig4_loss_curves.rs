//! Figure 4 — pre-training loss for serial (blue), pure layer-parallel
//! (red), and parallel→serial switching (green) on the BERT / GPT / ViT
//! analogues. Pure layer-parallel eventually drifts from the serial
//! dynamics (biased gradients); the indicator-driven switch recovers them.
//! The BERT panel sweeps three seeds (the paper's grey min/max band).

use layertime::config::{presets, MgritConfig, OptKind, RunConfig};
use layertime::coordinator::{Task, TrainReport, TrainRun};
use layertime::model::{Init, ParamStore};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, i, Table};

fn three_way(
    rc: &RunConfig,
    task: Task,
    init_scheme: Init,
) -> anyhow::Result<(TrainReport, TrainReport, TrainReport)> {
    let init = ParamStore::init(&rc.model, init_scheme, rc.train.seed);
    let mut serial_rc = rc.clone();
    serial_rc.mgrit = MgritConfig::serial();
    serial_rc.train.adaptive = false;
    let mut s = TrainRun::from_params(serial_rc, task, init.deep_clone(), None)?;
    let mut pure_rc = rc.clone();
    pure_rc.train.adaptive = false;
    let mut p = TrainRun::from_params(pure_rc, task, init.deep_clone(), None)?;
    p.warm_start = false; // pure inexact solves each batch (paper's red curve)
    let mut sw_rc = rc.clone();
    sw_rc.train.adaptive = true;
    let mut w = TrainRun::from_params(sw_rc, task, init, None)?;
    w.warm_start = false;
    // bench-scale decision boundary (see fig5_indicator.rs)
    w.controller.rho_switch = 0.5;
    w.controller.rho_grow = 0.35;
    w.controller.max_iters = 2;
    Ok((s.train()?, p.train()?, w.train()?))
}

fn print_panel(name: &str, s: &TrainReport, p: &TrainReport, w: &TrainReport) {
    println!("{} loss curves:\n", name);
    let mut tbl = Table::new(&["step", "serial", "pure parallel", "switch"]);
    let n = s.curve.len();
    let mut csv = CsvWriter::create(
        format!("bench_out/fig4_{}.csv", name.to_lowercase()),
        &["step", "serial", "pure", "switch"],
    )
    .unwrap();
    for k in (0..n).step_by((n / 15).max(1)) {
        tbl.row(vec![
            i(s.curve[k].step as i64),
            f(s.curve[k].loss as f64, 4),
            f(p.curve[k].loss as f64, 4),
            f(w.curve[k].loss as f64, 4),
        ]);
    }
    for k in 0..n {
        csv.row(&[
            s.curve[k].step.to_string(),
            s.curve[k].loss.to_string(),
            p.curve[k].loss.to_string(),
            w.curve[k].loss.to_string(),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    tbl.print();
    let drift = |a: &TrainReport, b: &TrainReport| -> f64 {
        a.curve
            .iter()
            .zip(&b.curve)
            .map(|(x, y)| (x.loss - y.loss).abs() as f64)
            .fold(0.0, f64::max)
    };
    let tail_drift = |a: &TrainReport, b: &TrainReport| -> f64 {
        let n = a.curve.len();
        let k = n.saturating_sub(n / 5).max(1);
        a.curve[k..]
            .iter()
            .zip(&b.curve[k..])
            .map(|(x, y)| (x.loss - y.loss).abs() as f64)
            .sum::<f64>()
            / (n - k) as f64
    };
    println!(
        "max |Δloss| vs serial: pure {:.4}, switch {:.4}; final-window mean: pure {:.4}, switch {:.4} (switched at {})\n",
        drift(s, p),
        drift(s, w),
        tail_drift(s, p),
        tail_drift(s, w),
        w.switched_at.map(|v| v.to_string()).unwrap_or_else(|| "never".into())
    );
}

fn main() -> anyhow::Result<()> {
    println!("Figure 4: serial vs pure layer-parallel vs adaptive switch\n");

    // BERT analogue: deep MLM encoder, 1 fwd + 1 bwd iteration, cf=4 — with
    // three seeds for the grey band.
    let mut rc = presets::bert_deep();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 64;
    rc.mgrit =
        MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: false };
    rc.train.steps = 150;
    rc.train.eval_every = 1000;
    rc.train.probe_every = 15;
    rc.train.lr = 5e-3;
    rc.train.warmup = 10;
    rc.train.opt = OptKind::AdamW;
    let mut band: Vec<(f32, f32)> = vec![];
    let mut first: Option<(TrainReport, TrainReport, TrainReport)> = None;
    for seed in [0u64, 1, 2] {
        let mut rcs = rc.clone();
        rcs.train.seed = seed;
        let (s, p, w) = three_way(&rcs, Task::Mlm, Init::DeepNet)?;
        band.push((w.final_loss, s.final_loss));
        if first.is_none() {
            first = Some((s, p, w));
        }
    }
    let (s, p, w) = first.unwrap();
    print_panel("BERT", &s, &p, &w);
    println!(
        "seed band (switch final loss): min {:.4} max {:.4}\n",
        band.iter().map(|b| b.0).fold(f32::INFINITY, f32::min),
        band.iter().map(|b| b.0).fold(f32::NEG_INFINITY, f32::max)
    );

    // GPT analogue: decoder + buffer layers, serial fwd + 1 bwd iteration.
    let mut rc = presets::gpt_small();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_dec_layers = 64;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: false };
    rc.train.steps = 150;
    rc.train.eval_every = 1000;
    rc.train.probe_every = 15;
    rc.train.lr = 5e-3;
    rc.train.warmup = 10;
    let (s, p, w) = three_way(&rc, Task::Lm, Init::Default)?;
    print_panel("GPT", &s, &p, &w);

    // ViT analogue: 32-layer encoder classifier, serial fwd + 1 bwd.
    let mut rc = presets::vit_small();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 64;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: false };
    rc.train.steps = 150;
    rc.train.eval_every = 1000;
    rc.train.probe_every = 15;
    rc.train.lr = 3e-3;
    rc.train.warmup = 10;
    let (s, p, w) = three_way(&rc, Task::Cls, Init::Default)?;
    print_panel("ViT", &s, &p, &w);

    println!("paper shape check: pure parallel drifts from serial; switching");
    println!("recovers the serial dynamics (smaller max |Δloss|).");
    Ok(())
}
