//! Figure 12 — buffer-layer ablation (Appendix B): decoder-only training
//! with 20 layers, comparing
//!   buffer:    2+2 serial open/close layers (Δt=1), middle 16 with Δt=1/16
//!   no buffer: all 20 layers in the ParallelNet with Δt=1/20
//! Left panel: the two *serial* runs have indistinguishable loss.
//! Right panel: |serial − layer-parallel| loss gap — buffers shrink it.

use layertime::config::{presets, MgritConfig, RunConfig};
use layertime::coordinator::{Task, TrainReport, TrainRun};
use layertime::model::{Init, ParamStore};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, i, Table};

fn run(rc: &RunConfig, serial: bool, init: &ParamStore) -> anyhow::Result<TrainReport> {
    let mut rc = rc.clone();
    if serial {
        rc.mgrit = MgritConfig::serial();
    }
    rc.train.adaptive = false;
    let mut r = TrainRun::from_params(rc, Task::Lm, init.deep_clone(), None)?;
    r.warm_start = false;
    r.train()
}

fn main() -> anyhow::Result<()> {
    let steps = 80usize;
    let mk = |buffers: bool| -> RunConfig {
        let mut rc = presets::gpt_small();
        presets::shrink_for_bench(&mut rc);
        rc.model.n_dec_layers = 20;
        rc.model.buffer_open = if buffers { 2 } else { 0 };
        rc.model.buffer_close = if buffers { 2 } else { 0 };
        rc.mgrit =
            MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
        rc.train.steps = steps;
        rc.train.eval_every = 1000;
        rc.train.lr = 3e-3;
        rc
    };

    let rc_buf = mk(true);
    let rc_nobuf = mk(false);
    println!(
        "buffer config: middle {} layers at dt=1/{} | no-buffer: 20 layers at dt=1/20-equivalent (dt=1)",
        rc_buf.model.parallel_layers(),
        rc_buf.model.parallel_layers()
    );

    let init_b = ParamStore::init(&rc_buf.model, Init::Default, 0);
    let s_buf = run(&rc_buf, true, &init_b)?;
    let p_buf = run(&rc_buf, false, &init_b)?;
    let init_n = ParamStore::init(&rc_nobuf.model, Init::Default, 0);
    let s_nob = run(&rc_nobuf, true, &init_n)?;
    let p_nob = run(&rc_nobuf, false, &init_n)?;

    println!("\nFigure 12 (left): serial losses, buffer vs no-buffer\n");
    let mut tbl = Table::new(&["step", "serial+buffer", "serial no-buffer"]);
    for k in (0..steps).step_by((steps / 10).max(1)) {
        tbl.row(vec![
            i(s_buf.curve[k].step as i64),
            f(s_buf.curve[k].loss as f64, 4),
            f(s_nob.curve[k].loss as f64, 4),
        ]);
    }
    tbl.print();

    println!("\nFigure 12 (right): |layer-parallel − serial| loss gap\n");
    let mut tbl = Table::new(&["step", "gap with buffer", "gap no buffer"]);
    let mut csv = CsvWriter::create("bench_out/fig12_buffer.csv",
        &["step", "gap_buffer", "gap_nobuffer"])?;
    let (mut sum_b, mut sum_n) = (0.0f64, 0.0f64);
    for k in 0..steps {
        let gb = (p_buf.curve[k].loss - s_buf.curve[k].loss).abs() as f64;
        let gn = (p_nob.curve[k].loss - s_nob.curve[k].loss).abs() as f64;
        sum_b += gb;
        sum_n += gn;
        csv.row(&[k.to_string(), gb.to_string(), gn.to_string()])?;
        if k % (steps / 10).max(1) == 0 {
            tbl.row(vec![i(k as i64), f(gb, 5), f(gn, 5)]);
        }
    }
    tbl.print();
    csv.flush()?;
    println!(
        "\nmean gap: with buffers {:.5} vs without {:.5} ({}x reduction)",
        sum_b / steps as f64,
        sum_n / steps as f64,
        f(sum_n / sum_b.max(1e-12), 1)
    );
    println!("paper shape check: serial dynamics agree; buffers significantly");
    println!("reduce the layer-parallel vs serial loss difference.");
    Ok(())
}
