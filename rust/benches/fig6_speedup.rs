//! Figure 6 — speedup of layer-parallel training vs #devices for the three
//! encoder-only tasks, L=2:
//!   left   BERT (128 layers, cf=4, 1 fwd + 1 bwd iteration)
//!   middle MC   (encoder, cf=2, 2 fwd + 1 bwd)
//!   right  ViT  (32 layers, cf=4, serial fwd + 1 bwd)
//!
//! Produced by the calibrated performance simulator (DESIGN.md
//! §Substitutions — 1 CPU core here): Φ cost comes from the artifact
//! manifest FLOPs when available (or the paper-width FLOP formula), comm
//! follows the V100/A100 α+β model. Expected shape: ≤1 speedup possible at
//! 2 devices for small models, strong gains as depth/devices grow, then
//! saturation at N/c_f-way parallelism.

use layertime::parallel::{DeviceModel, SimConfig, Simulator};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, i, Table};

/// Paper-scale per-sample Φ FLOPs for width (d, ff, seq).
fn phi_flops(seq: usize, d: usize, ff: usize) -> f64 {
    (8 * seq * d * d + 4 * seq * seq * d + 4 * seq * d * ff) as f64
}

struct TaskRow {
    name: &'static str,
    layers: usize,
    cf: usize,
    fwd: Option<usize>,
    bwd: Option<usize>,
    seq: usize,
    d: usize,
    ff: usize,
    batch: usize,
    device: DeviceModel,
}

fn main() {
    let tasks = [
        TaskRow { name: "BERT", layers: 128, cf: 4, fwd: Some(1), bwd: Some(1),
                  seq: 224, d: 768, ff: 3072, batch: 32, device: DeviceModel::a100() },
        TaskRow { name: "MC", layers: 64, cf: 2, fwd: Some(2), bwd: Some(1),
                  seq: 2048, d: 128, ff: 128, batch: 8, device: DeviceModel::v100() },
        TaskRow { name: "ViT", layers: 32, cf: 4, fwd: None, bwd: Some(1),
                  seq: 196, d: 768, ff: 3072, batch: 4, device: DeviceModel::a100() },
    ];
    let devices = [1usize, 2, 4, 8, 16, 32];

    println!("Figure 6: layer-parallel speedup vs #GPUs (L=2), per task\n");
    let mut csv = CsvWriter::create("bench_out/fig6_speedup.csv",
        &["task", "devices", "time_s", "speedup"]).unwrap();
    for t in &tasks {
        let mut tbl = Table::new(&["devices", "time/batch (s)", "speedup"]);
        for &p in &devices {
            let sim = Simulator::new(SimConfig {
                n_layers: t.layers,
                cf: t.cf,
                levels: 2,
                fwd_iters: t.fwd,
                bwd_iters: t.bwd,
                fcf: true,
                lp: p,
                dp: 1,
                flops_per_sample_step: phi_flops(t.seq, t.d, t.ff),
                batch: t.batch,
                state_bytes: (t.seq * t.d * 4) as f64,
                param_bytes: (t.layers * (4 * t.d * t.d + 2 * t.d * t.ff)) as f64 * 4.0,
                device: t.device,
            });
            let time = sim.batch_time().total;
            let speedup = sim.speedup_vs_serial();
            tbl.row(vec![i(p as i64), f(time, 5), f(speedup, 2)]);
            csv.row(&[t.name.into(), p.to_string(), time.to_string(), speedup.to_string()])
                .unwrap();
        }
        println!("{} ({} layers, cf={}, fwd={:?}, bwd={:?}, {}):",
            t.name, t.layers, t.cf, t.fwd, t.bwd, t.device.name);
        tbl.print();
        println!();
    }
    csv.flush().unwrap();
    println!("series written to bench_out/fig6_speedup.csv");
    println!("paper shape check: 2-device speedup may be <1 (overhead), deeper tasks");
    println!("gain more, curves saturate near N/c_f devices.");
}
