//! Figure 3 — long-term training behaviour, serial vs layer-parallel
//! (bench-scale reproduction; DESIGN.md experiment index):
//!   left   MC validation accuracy, 64 transformer layers, L=2, cf=2 —
//!          layer-parallel matches serial accuracy.
//!   right  MT validation BLEU, 6-6 layers, cf=3 — pure layer-parallel can
//!          lag; switching parallel→serial ("2->1") recovers the serial
//!          score.

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Task, TrainRun};
use layertime::model::{Init, ParamStore};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, i, Table};

fn main() -> anyhow::Result<()> {
    // ---- left: MC, 64 layers, serial vs layer-parallel ---------------------
    let mut rc = presets::mc_tiny();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 64;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true };
    rc.train.steps = 120;
    rc.train.eval_every = 20;
    rc.train.adaptive = false;
    rc.train.opt = layertime::config::OptKind::Adam;
    rc.train.lr = 2e-3;

    let init = ParamStore::init(&rc.model, Init::DeepNet, rc.train.seed);
    let mut serial_rc = rc.clone();
    serial_rc.mgrit = MgritConfig::serial();
    let mut s_run = TrainRun::from_params(serial_rc, Task::Tag, init.deep_clone(), None)?;
    let s = s_run.train()?;
    let mut p_run = TrainRun::from_params(rc, Task::Tag, init, None)?;
    let p = p_run.train()?;

    println!("Figure 3 (left): MC val accuracy, 64 layers, L=2, cf=2\n");
    let mut tbl = Table::new(&["step", "serial (1 GPU)", "layer-parallel"]);
    let mut csv = CsvWriter::create("bench_out/fig3_mc.csv", &["step", "serial", "parallel"])?;
    for (a, b) in s.evals.iter().zip(&p.evals) {
        tbl.row(vec![i(a.step as i64), f(a.metric, 3), f(b.metric, 3)]);
        csv.row(&[a.step.to_string(), a.metric.to_string(), b.metric.to_string()])?;
    }
    tbl.print();
    csv.flush()?;
    println!(
        "final Δ accuracy (parallel − serial): {:+.3}\n",
        p.final_metric - s.final_metric
    );

    // ---- right: MT, 6-6 layers, serial vs pure-LP vs switch ----------------
    let mut rc = presets::mt_small();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 6;
    rc.model.n_dec_layers = 6;
    rc.mgrit = MgritConfig { cf: 3, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.steps = 150;
    rc.train.eval_every = 25;
    rc.train.lr = 2e-3;
    rc.train.warmup = 10;

    let init = ParamStore::init(&rc.model, Init::Default, rc.train.seed);
    let mut serial_rc = rc.clone();
    serial_rc.mgrit = MgritConfig::serial();
    serial_rc.train.adaptive = false;
    let mut s_run = TrainRun::from_params(serial_rc, Task::Translate, init.deep_clone(), None)?;
    let s = s_run.train()?;
    let mut pure_rc = rc.clone();
    pure_rc.train.adaptive = false;
    let mut pure_run = TrainRun::from_params(pure_rc, Task::Translate, init.deep_clone(), None)?;
    let pure = pure_run.train()?;
    let mut sw_rc = rc.clone();
    sw_rc.train.adaptive = true;
    sw_rc.train.probe_every = 30;
    let mut sw_run = TrainRun::from_params(sw_rc, Task::Translate, init, None)?;
    let sw = sw_run.train()?;

    println!("Figure 3 (right): MT val BLEU, 6-6 layers, cf=3\n");
    let mut tbl = Table::new(&["step", "serial", "pure parallel", "2->1 switch"]);
    let mut csv =
        CsvWriter::create("bench_out/fig3_mt.csv", &["step", "serial", "pure", "switch"])?;
    for ((a, b), c) in s.evals.iter().zip(&pure.evals).zip(&sw.evals) {
        tbl.row(vec![i(a.step as i64), f(a.metric, 4), f(b.metric, 4), f(c.metric, 4)]);
        csv.row(&[
            a.step.to_string(),
            a.metric.to_string(),
            b.metric.to_string(),
            c.metric.to_string(),
        ])?;
    }
    tbl.print();
    csv.flush()?;
    println!(
        "switched at: {} | final BLEU: serial {:.4}, pure {:.4}, switch {:.4}",
        sw.switched_at.map(|s| s.to_string()).unwrap_or_else(|| "never".into()),
        s.final_metric,
        pure.final_metric,
        sw.final_metric
    );
    println!("\npaper shape check: MC parallel ≈ serial; MT switch recovers serial BLEU.");
    Ok(())
}
