//! Figure 8 — impact of the MGRIT parameters on parallel scaling for the
//! MC task (2 fwd + 1 bwd iterations):
//!   left   levels L ∈ {2,3,4} at cf=2, N_enc=1024
//!   middle cf ∈ {2,4,8,16} at L=2, N_enc=1024
//!   right  depth N ∈ {128,256,512,1024} at L=3, cf=4
//! against the ideal-scaling line.

use layertime::parallel::{DeviceModel, SimConfig, Simulator};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, i, Table};

fn sim(n: usize, cf: usize, levels: usize, lp: usize) -> Simulator {
    let (seq, d, ff, batch) = (2048usize, 128usize, 128usize, 8usize);
    let phi = (8 * seq * d * d + 4 * seq * seq * d + 4 * seq * d * ff) as f64;
    Simulator::new(SimConfig {
        n_layers: n,
        cf,
        levels,
        fwd_iters: Some(2),
        bwd_iters: Some(1),
        fcf: true,
        lp,
        dp: 1,
        flops_per_sample_step: phi,
        batch,
        state_bytes: (seq * d * 4) as f64,
        param_bytes: (n * (4 * d * d + 2 * d * ff)) as f64 * 4.0,
        device: DeviceModel::v100(),
    })
}

fn main() {
    let devices = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let mut csv = CsvWriter::create("bench_out/fig8_mgrit_params.csv",
        &["panel", "param", "devices", "speedup"]).unwrap();

    println!("Figure 8 (left): levels L at cf=2, N=1024\n");
    let mut tbl = Table::new(&["devices", "L=2", "L=3", "L=4", "ideal"]);
    for &p in &devices {
        let mut row = vec![i(p as i64)];
        for l in [2usize, 3, 4] {
            let s = sim(1024, 2, l, p).speedup_vs_serial();
            row.push(f(s, 2));
            csv.row(&["levels".into(), l.to_string(), p.to_string(), s.to_string()]).unwrap();
        }
        row.push(f(p as f64, 0));
        tbl.row(row);
    }
    tbl.print();

    println!("\nFigure 8 (middle): coarsening factor cf at L=2, N=1024\n");
    let mut tbl = Table::new(&["devices", "cf=2", "cf=4", "cf=8", "cf=16"]);
    for &p in &devices {
        let mut row = vec![i(p as i64)];
        for cf in [2usize, 4, 8, 16] {
            let s = sim(1024, cf, 2, p).speedup_vs_serial();
            row.push(f(s, 2));
            csv.row(&["cf".into(), cf.to_string(), p.to_string(), s.to_string()]).unwrap();
        }
        tbl.row(row);
    }
    tbl.print();

    println!("\nFigure 8 (right): depth N at L=3, cf=4\n");
    let mut tbl = Table::new(&["devices", "N=128", "N=256", "N=512", "N=1024"]);
    for &p in &devices {
        let mut row = vec![i(p as i64)];
        for n in [128usize, 256, 512, 1024] {
            let s = sim(n, 4, 3, p).speedup_vs_serial();
            row.push(f(s, 2));
            csv.row(&["depth".into(), n.to_string(), p.to_string(), s.to_string()]).unwrap();
        }
        tbl.row(row);
    }
    tbl.print();
    csv.flush().unwrap();
    println!("\nseries written to bench_out/fig8_mgrit_params.csv");
    println!("paper shape check: more levels and larger cf improve scalability;");
    println!("benefits grow with depth N.");
}
