//! Figures 10 & 11 — per-layer Monte-Carlo Lipschitz estimates during
//! decoder-only (GPT) training, and the relative weight drift
//! ‖w−w₀‖/‖w₀‖ per layer. The paper's observation: the *last* layers'
//! Lipschitz constants move first, then the early layers, while middle
//! layers stay modest — motivating serial "buffer" layers at both ends
//! (Appendix B). Weight drift alone does not predict this (Fig. 11).

use layertime::analysis::{estimate_layer_lipschitz, weight_drift};
use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Task, TrainRun};
use layertime::ode::Propagator;
use layertime::tensor::Tensor;
use layertime::util::csv::CsvWriter;
use layertime::util::rng::Rng;
use layertime::util::table::{f, i, Table};

fn main() -> anyhow::Result<()> {
    let mut rc = presets::gpt_small();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_dec_layers = 12;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig::serial(); // paper estimates during *serial* training
    rc.train.adaptive = false;
    rc.train.steps = 0; // stepped manually below
    rc.train.lr = 3e-3;

    let n_layers = rc.model.total_layers();
    let checkpoints = [0usize, 30, 60, 90, 120];
    let mut run = TrainRun::new(rc, Task::Lm, None)?;
    let w0: Vec<Vec<f32>> = run.params.layers.read().unwrap().clone();

    let mut rng = Rng::new(777);
    let mut lip_rows: Vec<(usize, Vec<f32>)> = vec![];
    let mut drift_rows: Vec<(usize, Vec<f32>)> = vec![];
    let mut done = 0usize;
    for &cp in &checkpoints {
        for _ in done..cp {
            run.train_step();
        }
        done = cp;
        // representative states from a forward pass on a fresh batch
        let prop = run.params.rust_propagator();
        let z0 = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let mut states = vec![z0];
        for l in 0..n_layers {
            let next = prop.step(l, 1.0, &states[l]);
            states.push(next);
        }
        let lip = estimate_layer_lipschitz(&prop, &states, 8, 1e-2, &mut rng);
        let drift = weight_drift(&run.params.layers.read().unwrap(), &w0);
        lip_rows.push((cp, lip));
        drift_rows.push((cp, drift));
    }

    println!("Figure 10: per-layer Lipschitz estimates during GPT training\n");
    let mut header: Vec<String> = vec!["layer".into()];
    header.extend(checkpoints.iter().map(|c| format!("step {}", c)));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut tbl = Table::new(&header_refs);
    let mut csv = CsvWriter::create("bench_out/fig10_lipschitz.csv", &header_refs)?;
    for l in 0..n_layers {
        let mut row = vec![i(l as i64)];
        row.extend(lip_rows.iter().map(|(_, lip)| f(lip[l] as f64, 3)));
        csv.row(&row)?;
        tbl.row(row);
    }
    tbl.print();
    csv.flush()?;

    println!("\nFigure 11: relative weight drift ‖w−w₀‖/‖w₀‖ per layer\n");
    let mut tbl = Table::new(&header_refs);
    for l in 0..n_layers {
        let mut row = vec![i(l as i64)];
        row.extend(drift_rows.iter().map(|(_, d)| f(d[l] as f64, 4)));
        tbl.row(row);
    }
    tbl.print();

    // quantify the paper's claim at the final checkpoint
    let last = &lip_rows.last().unwrap().1;
    let first_l = last[0];
    let mid_l: f32 = last[n_layers / 2 - 1..n_layers / 2 + 1].iter().sum::<f32>() / 2.0;
    let last_l = last[n_layers - 1];
    println!(
        "\nfinal Lipschitz — first layer {:.3}, middle {:.3}, last layer {:.3}",
        first_l, mid_l, last_l
    );
    println!("paper shape check: the ends move away from the middle as training");
    println!("progresses → place serial buffer layers at both ends (Appendix B).");
    Ok(())
}
