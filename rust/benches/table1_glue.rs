//! Table 1 — fine-tuning comparison: a serially pre-trained checkpoint vs
//! an adaptive-switch (parallel→serial) pre-trained checkpoint, fine-tuned
//! on three downstream classification tasks (CoLA/MRPC/QNLI analogues:
//! three seed-distinct synthetic sentence-classification tasks). Reported
//! exactly like the paper: |Δ loss| and |Δ accuracy| between the two
//! fine-tuned models — small deltas mean layer-parallel pre-training is
//! as good a starting point as serial.

use layertime::config::{presets, MgritConfig, OptKind};
use layertime::coordinator::{Task, TrainRun};
use layertime::model::{Init, ParamStore};
use layertime::util::table::{f, Table};

fn main() -> anyhow::Result<()> {
    // --- pre-train twice from one init: serial and adaptive-switch ----------
    let mut rc = presets::bert_deep();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 16;
    rc.mgrit = MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true };
    rc.train.steps = 150;
    rc.train.eval_every = 1000;
    rc.train.probe_every = 30;
    rc.train.lr = 2e-3;
    rc.train.warmup = 15;
    rc.train.opt = OptKind::AdamW;

    let init = ParamStore::init(&rc.model, Init::Default, rc.train.seed);
    println!("pre-training (MLM, 16 layers): serial …");
    let mut serial_rc = rc.clone();
    serial_rc.mgrit = MgritConfig::serial();
    serial_rc.train.adaptive = false;
    let mut s_run = TrainRun::from_params(serial_rc, Task::Mlm, init.deep_clone(), None)?;
    s_run.train()?;
    println!("pre-training (MLM, 16 layers): adaptive switch …");
    let mut sw_rc = rc.clone();
    sw_rc.train.adaptive = true;
    let mut w_run = TrainRun::from_params(sw_rc, Task::Mlm, init, None)?;
    let wrep = w_run.train()?;
    println!(
        "  switch happened at: {}",
        wrep.switched_at.map(|s| s.to_string()).unwrap_or_else(|| "never".into())
    );

    // --- fine-tune both checkpoints on three downstream tasks ---------------
    // task seeds play the role of CoLA / MRPC / QNLI
    let tasks: [(&str, u64, usize); 3] =
        [("CoLA-like", 101, 40), ("MRPC-like", 202, 40), ("QNLI-like", 303, 40)];
    let mut tbl = Table::new(&["Task", "Δ in Loss", "Δ in Acc."]);
    for (name, seed, steps) in tasks {
        let mut ft = rc.clone();
        ft.mgrit = MgritConfig::serial(); // paper fine-tunes serially
        ft.train.adaptive = false;
        ft.train.steps = steps;
        ft.train.eval_every = steps;
        ft.train.seed = seed;
        ft.train.lr = 1e-3;
        ft.train.warmup = 4;
        ft.train.opt = OptKind::AdamW;

        let mut a = TrainRun::from_params(ft.clone(), Task::Cls, s_run.params.deep_clone(), None)?;
        // the image task needs a square seq; use classification over the
        // token stream instead: Tag->Cls is seq-level; our Cls data source
        // is images — square seq already satisfied by shrink (seq=16).
        let ra = a.train()?;
        let mut b = TrainRun::from_params(ft, Task::Cls, w_run.params.deep_clone(), None)?;
        let rb = b.train()?;
        tbl.row(vec![
            name.into(),
            format!("{:.2e}", (ra.final_loss - rb.final_loss).abs()),
            format!("{:.1}%", (ra.final_metric - rb.final_metric).abs() * 100.0),
        ]);
    }
    println!("\nTable 1: |serial-pretrained − switch-pretrained| after fine-tuning\n");
    tbl.print();
    println!("\npaper shape check: deltas are small (0–2% accuracy, ≲1e-2 loss) —");
    println!("layer-parallel pre-training + switching matches serial pre-training.");
    Ok(())
}
