//! Figure 5 — the §3.2.3 indicator (MGRIT convergence factor ρ, probed by
//! doubling the iteration count) over the course of training for the
//! BERT / ViT / GPT analogues. The paper's signal: ρ rises as the network
//! trains (growing layer Lipschitz constants) and crossing 1 marks the
//! moment to switch to exact gradients.

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Task, TrainRun};
use layertime::util::csv::CsvWriter;
use layertime::util::table::{f, i, Table};

fn run_with_probes(
    name: &str,
    mut rc: layertime::config::RunConfig,
    task: Task,
) -> anyhow::Result<()> {
    rc.train.adaptive = true;
    rc.train.probe_every = 10;
    rc.train.eval_every = 10_000;
    let mut run = TrainRun::new(rc, task, None)?;
    // bench-scale thresholds: at paper scale the switch fires when rho
    // crosses 1.0 after ~10^4-10^5 batches; at this width/step budget rho
    // stays lower, so the decision boundary is scaled down accordingly.
    run.controller.rho_switch = 0.5;
    run.controller.rho_grow = 0.35;
    let report = run.train()?;
    println!("{} indicator trace:\n", name);
    let mut tbl = Table::new(&["step", "rho_fwd", "rho_bwd", "decision"]);
    let mut csv = CsvWriter::create(
        format!("bench_out/fig5_{}.csv", name.to_lowercase()),
        &["step", "rho_fwd", "rho_bwd"],
    )?;
    for p in &report.probes {
        tbl.row(vec![
            i(p.step as i64),
            p.rho_fwd.map(|v| f(v, 4)).unwrap_or_else(|| "-".into()),
            p.rho_bwd.map(|v| f(v, 4)).unwrap_or_else(|| "-".into()),
            format!("{:?}", p.decision),
        ]);
        csv.row(&[
            p.step.to_string(),
            p.rho_fwd.map(|v| v.to_string()).unwrap_or_default(),
            p.rho_bwd.map(|v| v.to_string()).unwrap_or_default(),
        ])?;
    }
    tbl.print();
    csv.flush()?;
    let rhos: Vec<f64> = report.probes.iter().filter_map(|p| p.rho_bwd.or(p.rho_fwd)).collect();
    if rhos.len() >= 2 {
        println!(
            "ρ first/last: {:.4} -> {:.4}{}\n",
            rhos[0],
            rhos[rhos.len() - 1],
            report
                .switched_at
                .map(|s| format!(" | switched to serial at step {}", s))
                .unwrap_or_default()
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("Figure 5: MGRIT convergence-factor indicator during training\n");

    let mut rc = presets::bert_deep();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 64;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: false };
    rc.train.steps = 150;
    rc.train.lr = 5e-3;
    run_with_probes("BERT", rc, Task::Mlm)?;

    let mut rc = presets::vit_small();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_enc_layers = 64;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: false };
    rc.train.steps = 150;
    rc.train.lr = 3e-3;
    run_with_probes("ViT", rc, Task::Cls)?;

    let mut rc = presets::gpt_small();
    presets::shrink_for_bench(&mut rc);
    rc.model.n_dec_layers = 64;
    rc.model.buffer_open = 0;
    rc.model.buffer_close = 0;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: false };
    rc.train.steps = 150;
    rc.train.lr = 5e-3;
    run_with_probes("GPT", rc, Task::Lm)?;

    println!("paper shape check: ρ drifts upward as training sharpens the");
    println!("layers; crossing 1 triggers the switch decision.");
    Ok(())
}
