"""AOT compiler: lower every L2 entry point to HLO *text* + manifest.json.

Run once at build time (`make artifacts`); the rust coordinator then loads
the artifacts through the PJRT C API and Python never appears on the
training path.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Lowering goes through
stablehlo -> XlaComputation with return_tuple=True, so the rust side always
unwraps a tuple.

Usage:
    python -m compile.aot --out ../artifacts [--d-model 64 --seq 32 ...]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import attention, mlp, ref


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _shape_desc(s) -> dict:
    dt = {"float32": "f32", "int32": "i32"}[str(s.dtype)]
    return {"shape": list(s.shape), "dtype": dt}


def lower_all(cfg: model.ModelConfig, out_dir: str,
              use_pallas: bool = True) -> dict:
    """Lower every entry point; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries = {}
    for name, (fn, args) in model.entry_points(cfg, use_pallas=use_pallas).items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *args)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        entries[name] = {
            "file": fname,
            "inputs": [_shape_desc(a) for a in args],
            "outputs": [_shape_desc(o) for o in out_shapes],
        }
        print(f"  lowered {name:<16} -> {fname} ({len(text)} chars)")

    manifest = {
        "format": "hlo-text/v1",
        "use_pallas": use_pallas,
        "config": cfg.to_json(),
        "param_layout": ref.param_layout(cfg.dims),
        "flops": {
            "enc_step": model.step_flops(cfg, decoder=False),
            "dec_step": model.step_flops(cfg, decoder=True),
        },
        "vmem": {
            "attention_bytes": attention.vmem_footprint_bytes(
                cfg.seq, cfg.seq, cfg.dims.head_dim, cfg.block_q, cfg.block_k),
            "mlp_bytes": mlp.vmem_footprint_bytes(
                cfg.d_model, cfg.d_ff, cfg.block_rows),
        },
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=128)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-classes", type=int, default=8)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference instead of Pallas")
    args = ap.parse_args()

    cfg = model.ModelConfig(
        vocab=args.vocab, d_model=args.d_model, n_heads=args.n_heads,
        d_ff=args.d_ff, seq=args.seq, batch=args.batch,
        n_classes=args.n_classes)
    print(f"AOT-lowering {cfg} -> {args.out}")
    m = lower_all(cfg, args.out, use_pallas=not args.no_pallas)
    print(f"wrote {len(m['entries'])} entry points + manifest.json")


if __name__ == "__main__":
    main()
