"""L2: the neural-ODE transformer compute graph (build-time JAX).

Composes the L1 Pallas kernels (kernels/attention.py, kernels/mlp.py) into
the paper's Euler step functions Phi and exposes every AOT entry point the
rust coordinator executes through PJRT:

    enc_step / causal_step / dec_step        — Phi (forward propagator)
    *_vjp                                    — adjoint step + parameter grads
    embed / embed_vjp                        — token+positional embedding
    lm_loss / lm_loss_vjp                    — (masked) token cross-entropy
    cls_loss / cls_loss_vjp                  — sequence classification head
    tag_loss / tag_loss_vjp                  — per-token tagging head

Autodiff note: pallas_call has no built-in VJP rule, so each Pallas-backed
step is wrapped in jax.custom_vjp whose backward pass differentiates the
*reference* implementation (kernels/ref.py). pytest pins kernel == ref, so
forward (Pallas) and backward (ref-VJP) are mutually consistent; a single
lowered `*_vjp` program therefore contains the Pallas forward recompute and
the exact adjoint in one fused HLO module.

The step size h is a runtime scalar input: one artifact serves every MGRIT
level (level l evaluates the same Phi with h * c_f^l).
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import attention_core as pallas_attention
from .kernels.mlp import phi2_pallas


@dataclass(frozen=True)
class ModelConfig:
    """Full model + batch geometry baked into one artifact set."""

    vocab: int = 64
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 128
    seq: int = 32
    batch: int = 8
    n_classes: int = 8
    block_q: int = 32
    block_k: int = 32
    block_rows: int = 64

    @property
    def dims(self) -> ref.ModelDims:
        return ref.ModelDims(self.d_model, self.n_heads, self.d_ff)

    @property
    def p_enc(self) -> int:
        return ref.layout_size(ref.enc_layout(self.dims))

    @property
    def p_dec(self) -> int:
        return ref.layout_size(ref.dec_layout(self.dims))

    def to_json(self) -> dict:
        d = asdict(self)
        d["p_enc"] = self.p_enc
        d["p_dec"] = self.p_dec
        d["head_dim"] = self.dims.head_dim
        return d


# ---------------------------------------------------------------------------
# Pallas-backed phi sublayers
# ---------------------------------------------------------------------------

def _phi1_pallas(x, p, cfg: ModelConfig, causal: bool):
    """phi1 with the flash-attention Pallas core (projections stay in XLA,
    which fuses them; the quadratic core runs in the kernel)."""
    z = ref.layer_norm(x, p["ln1_g"], p["ln1_b"])
    q = ref.split_heads(z @ p["wq"], cfg.n_heads)
    k = ref.split_heads(z @ p["wk"], cfg.n_heads)
    v = ref.split_heads(z @ p["wv"], cfg.n_heads)
    a = pallas_attention(q, k, v, causal=causal,
                         block_q=cfg.block_q, block_k=cfg.block_k)
    return ref.merge_heads(a) @ p["wo"]


def _phi3_pallas(y, x_enc, p, cfg: ModelConfig):
    z = ref.layer_norm(y, p["ln3_g"], p["ln3_b"])
    q = ref.split_heads(z @ p["cq"], cfg.n_heads)
    k = ref.split_heads(x_enc @ p["ck"], cfg.n_heads)
    v = ref.split_heads(x_enc @ p["cv"], cfg.n_heads)
    a = pallas_attention(q, k, v, causal=False,
                         block_q=cfg.block_q, block_k=cfg.block_k)
    return ref.merge_heads(a) @ p["co"]


def _phi2(x, p, cfg: ModelConfig):
    return phi2_pallas(x, p["ln2_g"], p["ln2_b"], p["w1"], p["b1"],
                       p["w2"], p["b2"], block_rows=cfg.block_rows)


def _enc_step_pallas(x, theta, h, cfg: ModelConfig, causal: bool):
    p = ref.unflatten(theta, ref.enc_layout(cfg.dims))
    a = _phi1_pallas(x, p, cfg, causal)
    return x + h * (a + _phi2(x + a, p, cfg))


def _dec_step_pallas(y, x_enc, theta, h, cfg: ModelConfig):
    p = ref.unflatten(theta, ref.dec_layout(cfg.dims))
    a = _phi1_pallas(y, p, cfg, causal=True)
    ybar = a + _phi3_pallas(y + a, x_enc, p, cfg)
    return y + h * (ybar + _phi2(y + ybar, p, cfg))


# ---------------------------------------------------------------------------
# custom-vjp step functions (Pallas forward, ref adjoint)
# ---------------------------------------------------------------------------

def make_enc_step(cfg: ModelConfig, causal: bool, use_pallas: bool = True):
    """Returns step(x, theta, h) -> x' with a ref-based custom VJP."""

    def ref_step(x, theta, h):
        return ref.enc_step(x, theta, h, cfg.dims, causal=causal)

    if not use_pallas:
        return ref_step

    @jax.custom_vjp
    def step(x, theta, h):
        return _enc_step_pallas(x, theta, h, cfg, causal)

    def fwd(x, theta, h):
        return step(x, theta, h), (x, theta, h)

    def bwd(res, ct):
        x, theta, h = res
        _, vjp = jax.vjp(ref_step, x, theta, h)
        return vjp(ct)

    step.defvjp(fwd, bwd)
    return step


def make_dec_step(cfg: ModelConfig, use_pallas: bool = True):
    """Returns step(y, x_enc, theta, h) -> y' with a ref-based custom VJP."""

    def ref_step(y, x_enc, theta, h):
        return ref.dec_step(y, x_enc, theta, h, cfg.dims)

    if not use_pallas:
        return ref_step

    @jax.custom_vjp
    def step(y, x_enc, theta, h):
        return _dec_step_pallas(y, x_enc, theta, h, cfg)

    def fwd(y, x_enc, theta, h):
        return step(y, x_enc, theta, h), (y, x_enc, theta, h)

    def bwd(res, ct):
        y, x_enc, theta, h = res
        _, vjp = jax.vjp(ref_step, y, x_enc, theta, h)
        return vjp(ct)

    step.defvjp(fwd, bwd)
    return step


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def entry_points(cfg: ModelConfig, use_pallas: bool = True) -> dict:
    """name -> (callable, example_args). Everything the rust runtime loads."""
    f32, i32 = jnp.float32, jnp.int32
    B, S, D, V, C = cfg.batch, cfg.seq, cfg.d_model, cfg.vocab, cfg.n_classes

    x = jax.ShapeDtypeStruct((B, S, D), f32)
    th_e = jax.ShapeDtypeStruct((cfg.p_enc,), f32)
    th_d = jax.ShapeDtypeStruct((cfg.p_dec,), f32)
    h = jax.ShapeDtypeStruct((), f32)
    tok = jax.ShapeDtypeStruct((B, S), i32)
    msk = jax.ShapeDtypeStruct((B, S), f32)
    lbl = jax.ShapeDtypeStruct((B,), i32)

    enc = make_enc_step(cfg, causal=False, use_pallas=use_pallas)
    cau = make_enc_step(cfg, causal=True, use_pallas=use_pallas)
    dec = make_dec_step(cfg, use_pallas=use_pallas)

    def enc_vjp(xv, th, hv, ct):
        _, vjp = jax.vjp(enc, xv, th, hv)
        lam, g, _ = vjp(ct)
        return lam, g

    def cau_vjp(xv, th, hv, ct):
        _, vjp = jax.vjp(cau, xv, th, hv)
        lam, g, _ = vjp(ct)
        return lam, g

    def dec_vjp(yv, xe, th, hv, ct):
        _, vjp = jax.vjp(dec, yv, xe, th, hv)
        lam_y, lam_x, g, _ = vjp(ct)
        return lam_y, lam_x, g

    w_emb = jax.ShapeDtypeStruct((V, D), f32)
    w_pos = jax.ShapeDtypeStruct((S, D), f32)
    w_out = jax.ShapeDtypeStruct((D, V), f32)
    w_cls = jax.ShapeDtypeStruct((D, C), f32)

    def embed_vjp(tk, ct):
        we = jnp.zeros((V, D), f32)
        wp = jnp.zeros((S, D), f32)
        _, vjp = jax.vjp(lambda we_, wp_: ref.embed(tk, we_, wp_), we, wp)
        return vjp(ct)

    def lm_loss_vjp(xv, w, tgt, m):
        (loss, correct), vjp = jax.vjp(
            lambda xv_, w_: ref.lm_loss(xv_, w_, tgt, m), xv, w)
        lam, gw = vjp((jnp.float32(1.0), jnp.float32(0.0)))
        return loss, correct, lam, gw

    def cls_loss_vjp(xv, w, lb):
        (loss, correct), vjp = jax.vjp(
            lambda xv_, w_: ref.cls_loss(xv_, w_, lb), xv, w)
        lam, gw = vjp((jnp.float32(1.0), jnp.float32(0.0)))
        return loss, correct, lam, gw

    def tag_loss_vjp(xv, w, lb):
        (loss, correct), vjp = jax.vjp(
            lambda xv_, w_: ref.tag_loss(xv_, w_, lb), xv, w)
        lam, gw = vjp((jnp.float32(1.0), jnp.float32(0.0)))
        return loss, correct, lam, gw

    tags = jax.ShapeDtypeStruct((B, S), i32)

    return {
        "enc_step": (lambda a, b_, c: (enc(a, b_, c),), (x, th_e, h)),
        "enc_step_vjp": (enc_vjp, (x, th_e, h, x)),
        "causal_step": (lambda a, b_, c: (cau(a, b_, c),), (x, th_e, h)),
        "causal_step_vjp": (cau_vjp, (x, th_e, h, x)),
        "dec_step": (lambda a, b_, c, d: (dec(a, b_, c, d),), (x, x, th_d, h)),
        "dec_step_vjp": (dec_vjp, (x, x, th_d, h, x)),
        "embed": (lambda t, we, wp: (ref.embed(t, we, wp),), (tok, w_emb, w_pos)),
        "embed_vjp": (embed_vjp, (tok, x)),
        "lm_loss": (lambda a, w, t, m: ref.lm_loss(a, w, t, m), (x, w_out, tok, msk)),
        "lm_loss_vjp": (lm_loss_vjp, (x, w_out, tok, msk)),
        "cls_loss": (lambda a, w, l: ref.cls_loss(a, w, l), (x, w_cls, lbl)),
        "cls_loss_vjp": (cls_loss_vjp, (x, w_cls, lbl)),
        "tag_loss": (lambda a, w, l: ref.tag_loss(a, w, l), (x, w_cls, tags)),
        "tag_loss_vjp": (tag_loss_vjp, (x, w_cls, tags)),
    }


def step_flops(cfg: ModelConfig, decoder: bool = False) -> int:
    """Rough FLOP count of one Phi application (feeds the L3 simulator)."""
    B, S, D, F = cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff
    attn = 4 * B * S * D * D * 2 + 2 * B * S * S * D * 2  # qkvo + core
    mlp_f = 2 * B * S * D * F * 2
    total = attn + mlp_f
    if decoder:
        total += attn  # cross-attention
    return total
