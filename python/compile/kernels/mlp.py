"""Pallas fused pre-LN MLP kernel (phi2 = MLP o LN) — second L1 hot-spot.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the activation matrix
[B*S, D] is tiled into row blocks that stay VMEM-resident across the whole
LN -> GEMM -> GELU -> GEMM chain, so the intermediate [block_rows, d_ff]
tensor never round-trips to HBM — the fusion a GPU implementation gets from
a handwritten epilogue kernel. Weight panels W1 [D,F], W2 [F,D] are small
enough at the paper's widths to remain resident; both GEMMs use `jnp.dot`
with preferred_element_type=f32 to target the MXU.

interpret=True for CPU-PJRT execution; oracle is `ref.mlp(ref.layer_norm(.))`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LN_EPS = 1e-5


def _pick_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def _ln_mlp_kernel(x_ref, g_ref, b_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One row-tile: out = GELU(LN(x) @ W1 + b1) @ W2 + b2."""
    x = x_ref[...]  # [block_rows, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    z = (x - mu) * jax.lax.rsqrt(var + LN_EPS) * g_ref[...] + b_ref[...]
    hmid = jnp.dot(z, w1_ref[...], preferred_element_type=jnp.float32)
    hmid = jax.nn.gelu(hmid + b1_ref[...], approximate=True)
    o_ref[...] = jnp.dot(hmid, w2_ref[...],
                         preferred_element_type=jnp.float32) + b2_ref[...]


def fused_ln_mlp(x2d: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray,
                 w1: jnp.ndarray, b1: jnp.ndarray, w2: jnp.ndarray,
                 b2: jnp.ndarray, *, block_rows: int = 64,
                 interpret: bool = True) -> jnp.ndarray:
    """phi2 core on flattened activations: x2d [R, D] -> [R, D]."""
    r, d = x2d.shape
    f = w1.shape[1]
    br = _pick_block(r, block_rows)

    full = lambda i: (0,)            # 1-D params replicated to every program
    full2 = lambda i: (0, 0)         # 2-D weight panels likewise
    return pl.pallas_call(
        _ln_mlp_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), full), pl.BlockSpec((d,), full),
            pl.BlockSpec((d, f), full2), pl.BlockSpec((f,), full),
            pl.BlockSpec((f, d), full2), pl.BlockSpec((d,), full),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(x2d, g, b, w1, b1, w2, b2)


def phi2_pallas(x: jnp.ndarray, g, b, w1, b1, w2, b2, *,
                block_rows: int = 64, interpret: bool = True) -> jnp.ndarray:
    """[B,S,D]-shaped wrapper matching `ref.phi2` (params unpacked)."""
    bsz, s, d = x.shape
    out = fused_ln_mlp(x.reshape(bsz * s, d), g, b, w1, b1, w2, b2,
                       block_rows=block_rows, interpret=interpret)
    return out.reshape(bsz, s, d)


def vmem_footprint_bytes(d: int, f: int, block_rows: int = 64) -> int:
    """VMEM bytes one grid program holds (f32): x tile + weights + hidden."""
    fb = 4
    return (block_rows * d * 2 + d * f * 2 + block_rows * f + 2 * d + f) * fb
