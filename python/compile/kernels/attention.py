"""Pallas flash-attention kernel — the L1 compute hot-spot.

The paper's GPU hot-spot is the quadratic attention core inside phi1/phi3.
Rethought for TPU (DESIGN.md §Hardware-Adaptation): instead of CUDA
threadblocks + shared memory, we express the HBM<->VMEM schedule with a
`BlockSpec` grid over (batch*heads, query tiles). Each grid program keeps a
[block_q, head_dim] query tile plus a running (max, sum, acc) softmax state
resident in VMEM and streams key/value tiles through it (the classic
flash-attention recurrence). Contractions use `jnp.dot` with
preferred_element_type=f32 so the TPU lowering targets the MXU.

`interpret=True` is mandatory on this testbed: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Correctness is
pinned against the pure-jnp oracle `ref.attention_core` by pytest.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pick_block(n: int, want: int) -> int:
    """Largest divisor of n that is <= want (block shapes must tile exactly)."""
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return b


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                      seq_k: int, causal: bool, block_q: int):
    """One grid program: queries tile (i, j) against all key/value tiles."""
    qb = q_ref[0]  # [block_q, hd] VMEM-resident
    hd = qb.shape[-1]
    scale = 1.0 / math.sqrt(hd)

    j = pl.program_id(1)
    q_pos = j * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    m = jnp.full((block_q, 1), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc = jnp.zeros((block_q, hd), dtype=jnp.float32)

    # Static (unrolled) stream over K/V tiles: each iteration touches one
    # [block_k, hd] panel — this is the HBM->VMEM pipeline a TPU would
    # double-buffer.
    for kc in range(seq_k // block_k):
        kb = k_ref[0, kc * block_k:(kc + 1) * block_k, :]
        vb = v_ref[0, kc * block_k:(kc + 1) * block_k, :]
        s = jnp.dot(qb, kb.T, preferred_element_type=jnp.float32) * scale
        if causal:
            k_pos = kc * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, vb, preferred_element_type=jnp.float32)
        m = m_new

    o_ref[0] = acc / l


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = False, block_q: int = 32,
                    block_k: int = 32, interpret: bool = True) -> jnp.ndarray:
    """softmax(q k^T / sqrt(hd)) v for q,k,v of shape [BH, S, hd].

    Drop-in replacement for `ref.attention_core` (after head split); supports
    self- and cross-attention (different key length) plus causal masking.
    """
    bh, sq, hd = q.shape
    sk = k.shape[1]
    bq = _pick_block(sq, block_q)
    bk = _pick_block(sk, block_k)

    kernel = functools.partial(_attention_kernel, block_k=bk, seq_k=sk,
                               causal=causal, block_q=bq)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, sk, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, sk, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def attention_core(q4: jnp.ndarray, k4: jnp.ndarray, v4: jnp.ndarray, *,
                   causal: bool = False, interpret: bool = True,
                   block_q: int = 32, block_k: int = 32) -> jnp.ndarray:
    """[B,H,S,hd]-shaped wrapper matching `ref.attention_core`'s signature."""
    b, h, sq, hd = q4.shape
    sk = k4.shape[2]
    out = flash_attention(
        q4.reshape(b * h, sq, hd), k4.reshape(b * h, sk, hd),
        v4.reshape(b * h, sk, hd), causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out.reshape(b, h, sq, hd)


def vmem_footprint_bytes(seq_q: int, seq_k: int, hd: int,
                         block_q: int = 32, block_k: int = 32) -> int:
    """Estimated VMEM bytes one grid program keeps live (f32).

    q tile + k/v panels (double-buffered) + softmax state + acc + out tile.
    Used by the §Perf roofline notes in EXPERIMENTS.md.
    """
    bq = _pick_block(seq_q, block_q)
    bk = _pick_block(seq_k, block_k)
    f = 4  # bytes per f32
    q_tile = bq * hd * f
    kv_panels = 2 * 2 * bk * hd * f  # k and v, double-buffered
    state = (2 * bq + 2 * bq * hd) * f  # m, l, acc, out
    scores = bq * bk * f
    return q_tile + kv_panels + state + scores
