"""Pure-jnp reference (oracle) for the neural-ODE transformer steps.

Implements eq. (1)-(3) of "Layer-Parallel Training for Transformers":
pre-LN transformer blocks viewed as a forward-Euler step

    X_{n+1} = X_n + h * F_enc(t_n, X_n),
    F_enc(x) = phi1(x) + phi2(x + phi1(x)),
    phi1 = SA o LN,  phi2 = MLP o LN,

and for encoder-decoder (eq. 2):

    Ybar   = phi1(y) + phi3(y + phi1(y), X_enc),
    Y_{n+1}= Y_n + h * (Ybar + phi2(Y_n + Ybar)),
    phi3 = CA o LN   (cross-attention).

Everything here is plain jax.numpy: this module is the correctness oracle
the Pallas kernels (kernels/attention.py, kernels/mlp.py) are tested
against, and it supplies the VJPs used by the AOT backward entry points.

Parameter layout (flat theta vector) — MUST stay in sync with
`param_layout()` below, which is exported to artifacts/manifest.json and
consumed by the rust coordinator (rust/src/model/spec.rs).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

LN_EPS = 1e-5


class ModelDims(NamedTuple):
    """Shape hyperparameters of one transformer stack (see paper Table 2)."""

    d_model: int
    n_heads: int
    d_ff: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# flat parameter layout
# ---------------------------------------------------------------------------

def enc_layout(dims: ModelDims):
    """(name, shape) pairs, in order, for one encoder (or decoder-only) layer."""
    d, f = dims.d_model, dims.d_ff
    return [
        ("ln1_g", (d,)), ("ln1_b", (d,)),
        ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)), ("wo", (d, d)),
        ("ln2_g", (d,)), ("ln2_b", (d,)),
        ("w1", (d, f)), ("b1", (f,)),
        ("w2", (f, d)), ("b2", (d,)),
    ]


def dec_layout(dims: ModelDims):
    """Layout for one encoder-decoder *decoder* layer (adds LN3 + cross-attn)."""
    d = dims.d_model
    return enc_layout(dims) + [
        ("ln3_g", (d,)), ("ln3_b", (d,)),
        ("cq", (d, d)), ("ck", (d, d)), ("cv", (d, d)), ("co", (d, d)),
    ]


def layout_size(layout) -> int:
    return sum(math.prod(s) for _, s in layout)


def unflatten(theta: jnp.ndarray, layout) -> dict:
    """Split a flat parameter vector into named tensors per the layout."""
    out, off = {}, 0
    for name, shape in layout:
        n = math.prod(shape)
        out[name] = theta[off:off + n].reshape(shape)
        off += n
    return out


def flatten(params: dict, layout) -> jnp.ndarray:
    return jnp.concatenate([params[name].reshape(-1) for name, _ in layout])


def param_layout(dims: ModelDims) -> dict:
    """Manifest-ready description of the per-layer flat layouts."""

    def describe(layout):
        entries, off = [], 0
        for name, shape in layout:
            n = math.prod(shape)
            entries.append({"name": name, "shape": list(shape), "offset": off, "size": n})
            off += n
        return {"params": entries, "total": off}

    return {"encoder_layer": describe(enc_layout(dims)),
            "decoder_layer": describe(dec_layout(dims))}


# ---------------------------------------------------------------------------
# primitive blocks
# ---------------------------------------------------------------------------

def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """LayerNorm over the trailing (feature) axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * g + b


def split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B,S,D] -> [B,H,S,hd]."""
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[B,H,S,hd] -> [B,S,D]."""
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def attention_core(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   causal: bool = False) -> jnp.ndarray:
    """softmax(q k^T / sqrt(hd)) v over [B,H,Sq,hd] x [B,H,Sk,hd]."""
    hd = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def mha(x: jnp.ndarray, kv: jnp.ndarray, wq, wk, wv, wo, n_heads: int,
        causal: bool = False) -> jnp.ndarray:
    """Multi-head attention; self-attention when kv is x, cross otherwise."""
    q = split_heads(x @ wq, n_heads)
    k = split_heads(kv @ wk, n_heads)
    v = split_heads(kv @ wv, n_heads)
    return merge_heads(attention_core(q, k, v, causal=causal)) @ wo


def mlp(x: jnp.ndarray, w1, b1, w2, b2) -> jnp.ndarray:
    """Position-wise feed-forward with GELU."""
    return jax.nn.gelu(x @ w1 + b1, approximate=True) @ w2 + b2


# ---------------------------------------------------------------------------
# the paper's phi sublayers and Euler steps
# ---------------------------------------------------------------------------

def phi1(x, p, n_heads: int, causal: bool):
    """phi1 = SA o LN (self-attention on the layer-normed input)."""
    z = layer_norm(x, p["ln1_g"], p["ln1_b"])
    return mha(z, z, p["wq"], p["wk"], p["wv"], p["wo"], n_heads, causal=causal)


def phi2(x, p):
    """phi2 = MLP o LN."""
    return mlp(layer_norm(x, p["ln2_g"], p["ln2_b"]), p["w1"], p["b1"], p["w2"], p["b2"])


def phi3(y, x_enc, p, n_heads: int):
    """phi3 = CA o LN (cross-attention: queries from y, keys/values from X_enc)."""
    z = layer_norm(y, p["ln3_g"], p["ln3_b"])
    return mha(z, x_enc, p["cq"], p["ck"], p["cv"], p["co"], n_heads, causal=False)


def f_enc(x, p, n_heads: int, causal: bool = False):
    """F_enc(x) = phi1(x) + phi2(x + phi1(x))   (eq. 1)."""
    a = phi1(x, p, n_heads, causal)
    return a + phi2(x + a, p)


def f_dec(y, x_enc, p, n_heads: int):
    """F_dec(y, X_enc) = Ybar + phi2(y + Ybar), Ybar = phi1(y)+phi3(y+phi1(y)) (eq. 2)."""
    a = phi1(y, p, n_heads, causal=True)
    ybar = a + phi3(y + a, x_enc, p, n_heads)
    return ybar + phi2(y + ybar, p)


def enc_step(x: jnp.ndarray, theta: jnp.ndarray, h: jnp.ndarray,
             dims: ModelDims, causal: bool = False) -> jnp.ndarray:
    """One forward-Euler layer step X_{n+1} = X_n + h F_enc(X_n)  (eq. 3)."""
    p = unflatten(theta, enc_layout(dims))
    return x + h * f_enc(x, p, dims.n_heads, causal=causal)


def dec_step(y: jnp.ndarray, x_enc: jnp.ndarray, theta: jnp.ndarray,
             h: jnp.ndarray, dims: ModelDims) -> jnp.ndarray:
    """One forward-Euler decoder step Y_{n+1} = Y_n + h F_dec(Y_n, X_enc)."""
    p = unflatten(theta, dec_layout(dims))
    return y + h * f_dec(y, x_enc, p, dims.n_heads)


# ---------------------------------------------------------------------------
# embeddings, heads, losses (entry points outside the ODE)
# ---------------------------------------------------------------------------

def embed(tokens: jnp.ndarray, w_emb: jnp.ndarray, w_pos: jnp.ndarray) -> jnp.ndarray:
    """Token + positional embedding: i32[B,S] -> f32[B,S,D]."""
    return w_emb[tokens] + w_pos[None, : tokens.shape[1], :]


def lm_loss(x: jnp.ndarray, w_out: jnp.ndarray, targets: jnp.ndarray,
            mask: jnp.ndarray):
    """Masked token-level cross-entropy (MLM when mask marks masked slots,
    causal LM when mask is all-ones). Returns (mean loss, #correct)."""
    logits = x @ w_out  # [B,S,V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == targets) * mask)
    return loss, correct


def cls_loss(x: jnp.ndarray, w_cls: jnp.ndarray, labels: jnp.ndarray):
    """Mean-pooled sequence classification CE. Returns (mean loss, #correct)."""
    pooled = jnp.mean(x, axis=1)  # [B,D]
    logits = pooled @ w_cls  # [B,C]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    correct = jnp.sum(jnp.argmax(logits, axis=-1) == labels)
    return jnp.mean(nll), correct


def tag_loss(x: jnp.ndarray, w_cls: jnp.ndarray, labels: jnp.ndarray):
    """Per-token tagging CE (morphological classification task). labels i32[B,S]."""
    logits = x @ w_cls  # [B,S,C]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    correct = jnp.sum(jnp.argmax(logits, axis=-1) == labels)
    return jnp.mean(nll), correct
