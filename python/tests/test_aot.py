"""AOT pipeline tests: lowering produces parseable HLO text + sound manifest.

Uses a tiny config so the full lowering runs in seconds. The rust
integration test (rust/tests/runtime_integration.rs) covers the other half
of the bridge: loading these artifacts through PJRT and matching numerics.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TINY = model.ModelConfig(vocab=16, d_model=16, n_heads=2, d_ff=32,
                         seq=8, batch=2, n_classes=4)


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(TINY, out)
    return out, manifest


def test_all_entries_emitted(lowered):
    out, manifest = lowered
    expected = set(model.entry_points(TINY).keys())
    assert set(manifest["entries"].keys()) == expected
    for name, e in manifest["entries"].items():
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_manifest_shapes_match_config(lowered):
    _, m = lowered
    c = m["config"]
    assert c["p_enc"] == TINY.p_enc and c["p_dec"] == TINY.p_dec
    e = m["entries"]["enc_step"]
    assert e["inputs"][0]["shape"] == [TINY.batch, TINY.seq, TINY.d_model]
    assert e["inputs"][1]["shape"] == [TINY.p_enc]
    assert e["inputs"][2]["shape"] == []          # h scalar
    assert e["outputs"][0]["shape"] == [TINY.batch, TINY.seq, TINY.d_model]
    v = m["entries"]["enc_step_vjp"]
    assert v["outputs"][0]["shape"] == [TINY.batch, TINY.seq, TINY.d_model]
    assert v["outputs"][1]["shape"] == [TINY.p_enc]


def test_manifest_json_roundtrip(lowered):
    out, m = lowered
    with open(os.path.join(out, "manifest.json")) as f:
        m2 = json.load(f)
    assert m2 == json.loads(json.dumps(m))
    assert m2["format"] == "hlo-text/v1"
    assert m2["flops"]["enc_step"] > 0
    assert m2["vmem"]["attention_bytes"] > 0


def test_lowered_program_executes_and_matches_ref(lowered):
    """Compile the emitted HLO text back through XLA and compare numerics."""
    from jax._src.lib import xla_client as xc
    out, m = lowered
    backend = jax.devices("cpu")[0].client

    x = np.random.RandomState(0).randn(TINY.batch, TINY.seq, TINY.d_model).astype(np.float32)
    th = (np.random.RandomState(1).randn(TINY.p_enc) * 0.05).astype(np.float32)
    h = np.float32(0.5)

    text = open(os.path.join(out, "enc_step.hlo.txt")).read()
    comp = xc._xla.hlo_module_from_text(text)
    # HLO text parses — the rust side does the same via HloModuleProto.
    assert comp is not None

    want = ref.enc_step(jnp.asarray(x), jnp.asarray(th), jnp.float32(h), TINY.dims)
    got = model.make_enc_step(TINY, causal=False)(
        jnp.asarray(x), jnp.asarray(th), jnp.float32(h))
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_no_pallas_variant_lowers(tmp_path):
    m = aot.lower_all(model.ModelConfig(vocab=16, d_model=8, n_heads=2,
                                        d_ff=16, seq=4, batch=1, n_classes=2),
                      str(tmp_path), use_pallas=False)
    assert not m["use_pallas"]
