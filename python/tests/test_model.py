"""L2 correctness: neural-ODE step functions, VJP entry points, losses.

Checks (a) Pallas-backed steps == reference steps, (b) every *_vjp entry
point == jax.grad of the forward, (c) the ODE/Euler structural properties
the MGRIT theory relies on (h -> 0 limit, residual form), (d) loss heads.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = model.ModelConfig(vocab=32, d_model=32, n_heads=4, d_ff=64,
                        seq=16, batch=2, n_classes=4)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@pytest.fixture(scope="module")
def data():
    x = rand(0, (CFG.batch, CFG.seq, CFG.d_model))
    th_e = rand(1, (CFG.p_enc,), 0.05)
    th_d = rand(2, (CFG.p_dec,), 0.05)
    return x, th_e, th_d


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
def test_pallas_step_matches_ref(data, causal):
    x, th_e, _ = data
    h = jnp.float32(0.5)
    step = model.make_enc_step(CFG, causal=causal)
    got = step(x, th_e, h)
    want = ref.enc_step(x, th_e, h, CFG.dims, causal=causal)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_dec_step_matches_ref(data):
    x, _, th_d = data
    y = rand(3, x.shape)
    h = jnp.float32(0.5)
    step = model.make_dec_step(CFG)
    got = step(y, x, th_d, h)
    want = ref.dec_step(y, x, th_d, h, CFG.dims)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_step_is_euler_residual(data):
    """X' - X must scale linearly in h (forward-Euler structure, eq. 3)."""
    x, th_e, _ = data
    step = model.make_enc_step(CFG, causal=False, use_pallas=False)
    d1 = step(x, th_e, jnp.float32(0.1)) - x
    d2 = step(x, th_e, jnp.float32(0.2)) - x
    np.testing.assert_allclose(2.0 * d1, d2, rtol=1e-4, atol=1e-5)


def test_step_h_zero_is_identity(data):
    x, th_e, _ = data
    step = model.make_enc_step(CFG, causal=False)
    np.testing.assert_allclose(step(x, th_e, jnp.float32(0.0)), x,
                               rtol=1e-6, atol=1e-6)


def test_causal_step_no_future_dependence(data):
    """Causal step output at position i ignores tokens at positions > i."""
    x, th_e, _ = data
    step = model.make_enc_step(CFG, causal=True, use_pallas=False)
    base = step(x, th_e, jnp.float32(1.0))
    x2 = x.at[:, -4:, :].add(7.0)
    pert = step(x2, th_e, jnp.float32(1.0))
    np.testing.assert_allclose(base[:, :-4], pert[:, :-4], rtol=1e-5, atol=1e-5)


def test_encoder_step_full_dependence(data):
    """Non-causal step: early positions DO see late tokens.

    Uses a larger parameter scale than the shared fixture: at scale 0.05 the
    softmax sensitivity of position 0 to a tail perturbation underflows f32.
    """
    x, _, _ = data
    th_e = rand(11, (CFG.p_enc,), 0.5)
    step = model.make_enc_step(CFG, causal=False, use_pallas=False)
    base = step(x, th_e, jnp.float32(1.0))
    pert = step(x.at[:, -1, :].add(7.0), th_e, jnp.float32(1.0))
    assert float(jnp.max(jnp.abs(base[:, 0] - pert[:, 0]))) > 1e-6


# ---------------------------------------------------------------------------
# VJP entry points vs jax.grad
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
def test_step_vjp_matches_grad(data, causal):
    x, th_e, _ = data
    h = jnp.float32(0.25)
    ct = rand(9, x.shape)
    step_ref = lambda xv, tv: ref.enc_step(xv, tv, h, CFG.dims, causal=causal)

    step = model.make_enc_step(CFG, causal=causal)
    _, vjp = jax.vjp(step, x, th_e, h)
    lam, g_th, _ = vjp(ct)

    def scalar(xv, tv):
        return jnp.vdot(step_ref(xv, tv), ct)

    g_x, g_t = jax.grad(scalar, argnums=(0, 1))(x, th_e)
    np.testing.assert_allclose(lam, g_x, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(g_th, g_t, rtol=1e-4, atol=1e-4)


def test_dec_step_vjp_matches_grad(data):
    x, _, th_d = data
    y = rand(4, x.shape)
    h = jnp.float32(0.25)
    ct = rand(9, x.shape)
    step = model.make_dec_step(CFG)
    _, vjp = jax.vjp(step, y, x, th_d, h)
    lam_y, lam_x, g_th, _ = vjp(ct)

    def scalar(yv, xv, tv):
        return jnp.vdot(ref.dec_step(yv, xv, tv, h, CFG.dims), ct)

    gy, gx, gt = jax.grad(scalar, argnums=(0, 1, 2))(y, x, th_d)
    np.testing.assert_allclose(lam_y, gy, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(lam_x, gx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(g_th, gt, rtol=1e-4, atol=1e-4)


def test_lm_loss_vjp_entry(data):
    x, _, _ = data
    w = rand(5, (CFG.d_model, CFG.vocab), 0.1)
    tgt = jax.random.randint(jax.random.PRNGKey(6), (CFG.batch, CFG.seq), 0, CFG.vocab)
    msk = jnp.ones((CFG.batch, CFG.seq), jnp.float32)
    eps = model.entry_points(CFG, use_pallas=False)
    loss, correct, lam, gw = eps["lm_loss_vjp"][0](x, w, tgt, msk)
    gl_x, gl_w = jax.grad(lambda xv, wv: ref.lm_loss(xv, wv, tgt, msk)[0],
                          argnums=(0, 1))(x, w)
    np.testing.assert_allclose(lam, gl_x, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw, gl_w, rtol=1e-4, atol=1e-5)
    assert 0 <= float(correct) <= CFG.batch * CFG.seq


def test_cls_and_tag_loss_vjp(data):
    x, _, _ = data
    w = rand(5, (CFG.d_model, CFG.n_classes), 0.1)
    eps = model.entry_points(CFG, use_pallas=False)

    lbl = jax.random.randint(jax.random.PRNGKey(7), (CFG.batch,), 0, CFG.n_classes)
    loss, correct, lam, gw = eps["cls_loss_vjp"][0](x, w, lbl)
    g = jax.grad(lambda xv: ref.cls_loss(xv, w, lbl)[0])(x)
    np.testing.assert_allclose(lam, g, rtol=1e-4, atol=1e-5)

    tags = jax.random.randint(jax.random.PRNGKey(8), (CFG.batch, CFG.seq), 0,
                              CFG.n_classes)
    loss, correct, lam, gw = eps["tag_loss_vjp"][0](x, w, tags)
    g = jax.grad(lambda xv: ref.tag_loss(xv, w, tags)[0])(x)
    np.testing.assert_allclose(lam, g, rtol=1e-4, atol=1e-5)


def test_embed_and_vjp():
    V, D, S, B = CFG.vocab, CFG.d_model, CFG.seq, CFG.batch
    we, wp = rand(1, (V, D)), rand(2, (S, D))
    tok = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, V)
    x = ref.embed(tok, we, wp)
    assert x.shape == (B, S, D)
    np.testing.assert_allclose(x[0, 0], we[tok[0, 0]] + wp[0], rtol=1e-6)

    eps = model.entry_points(CFG, use_pallas=False)
    ct = rand(4, (B, S, D))
    g_we, g_wp = eps["embed_vjp"][0](tok, ct)
    gw = jax.grad(lambda w: jnp.vdot(ref.embed(tok, w, wp), ct))(we)
    np.testing.assert_allclose(g_we, gw, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# layouts / config
# ---------------------------------------------------------------------------

def test_flatten_unflatten_roundtrip():
    layout = ref.enc_layout(CFG.dims)
    theta = rand(1, (CFG.p_enc,))
    p = ref.unflatten(theta, layout)
    np.testing.assert_allclose(ref.flatten(p, layout), theta)


def test_param_sizes():
    d, f = CFG.d_model, CFG.d_ff
    assert CFG.p_enc == 4 * d * d + 2 * d * f + 5 * d + f
    assert CFG.p_dec == CFG.p_enc + 2 * d + 4 * d * d


@settings(max_examples=10, deadline=None)
@given(d=st.sampled_from([8, 16, 32]), hds=st.sampled_from([1, 2, 4]),
       f=st.sampled_from([16, 32]))
def test_param_layout_manifest_consistent(d, hds, f):
    dims = ref.ModelDims(d, hds, f)
    pl_ = ref.param_layout(dims)
    for key, layout in (("encoder_layer", ref.enc_layout(dims)),
                        ("decoder_layer", ref.dec_layout(dims))):
        total = pl_[key]["total"]
        assert total == ref.layout_size(layout)
        off = 0
        for e, (name, shape) in zip(pl_[key]["params"], layout):
            assert e["name"] == name and tuple(e["shape"]) == shape
            assert e["offset"] == off
            off += e["size"]


def test_step_flops_positive():
    assert model.step_flops(CFG) > 0
    assert model.step_flops(CFG, decoder=True) > model.step_flops(CFG)
