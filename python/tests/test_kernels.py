"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

hypothesis sweeps shapes / head counts / block sizes / masks; every case
asserts allclose against the reference. This is the core correctness signal
for the kernels that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, mlp, ref

jax.config.update("jax_platform_name", "cpu")

RTOL = 2e-5
ATOL = 2e-5


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    sq=st.sampled_from([4, 8, 16, 24, 32]),
    hd=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    bq=st.sampled_from([4, 8, 16, 32]),
    bk=st.sampled_from([4, 8, 16, 32]),
)
def test_flash_attention_matches_ref(b, h, sq, hd, causal, bq, bk):
    q = rand(1, (b, h, sq, hd))
    k = rand(2, (b, h, sq, hd))
    v = rand(3, (b, h, sq, hd))
    got = attention.attention_core(q, k, v, causal=causal,
                                   block_q=bq, block_k=bk)
    want = ref.attention_core(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([4, 8, 16]), sk=st.sampled_from([8, 16, 32]))
def test_flash_attention_cross_lengths(sq, sk):
    """Cross-attention: query and key lengths differ."""
    q = rand(1, (2, 2, sq, 8))
    k = rand(2, (2, 2, sk, 8))
    v = rand(3, (2, 2, sk, 8))
    got = attention.attention_core(q, k, v, causal=False, block_q=4, block_k=8)
    want = ref.attention_core(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_flash_attention_causal_masks_future():
    """Output at position i must not depend on positions > i."""
    q = rand(1, (1, 1, 16, 8))
    k = rand(2, (1, 1, 16, 8))
    v = rand(3, (1, 1, 16, 8))
    base = attention.attention_core(q, k, v, causal=True, block_q=4, block_k=4)
    k2 = k.at[:, :, 12:, :].set(99.0)
    v2 = v.at[:, :, 12:, :].set(-99.0)
    pert = attention.attention_core(q, k2, v2, causal=True, block_q=4, block_k=4)
    np.testing.assert_allclose(base[:, :, :12], pert[:, :, :12],
                               rtol=RTOL, atol=ATOL)


def test_flash_attention_softmax_rows_convex():
    """Attention output lies in the convex hull of the value rows."""
    q = rand(1, (1, 1, 8, 4), scale=3.0)
    k = rand(2, (1, 1, 8, 4), scale=3.0)
    v = rand(3, (1, 1, 8, 4))
    out = attention.attention_core(q, k, v, block_q=4, block_k=4)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-5
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-5


def test_pick_block_divides():
    for n in [1, 2, 3, 7, 16, 24, 32, 100]:
        for want in [1, 4, 8, 32, 64]:
            b = attention._pick_block(n, want)
            assert n % b == 0 and 1 <= b <= max(1, min(want, n))


def test_vmem_footprint_reported():
    bytes_ = attention.vmem_footprint_bytes(128, 128, 64)
    assert 0 < bytes_ < 16 * 1024 * 1024  # fits VMEM


# ---------------------------------------------------------------------------
# fused LN+MLP
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([4, 8, 32, 48, 64]),
    d=st.sampled_from([8, 16, 32, 64]),
    f=st.sampled_from([16, 32, 128]),
    br=st.sampled_from([4, 16, 64]),
)
def test_fused_ln_mlp_matches_ref(rows, d, f, br):
    x = rand(1, (rows, d))
    g = rand(2, (d,), 0.2) + 1.0
    b = rand(3, (d,), 0.2)
    w1 = rand(4, (d, f), 0.3)
    b1 = rand(5, (f,), 0.1)
    w2 = rand(6, (f, d), 0.3)
    b2 = rand(7, (d,), 0.1)
    got = mlp.fused_ln_mlp(x, g, b, w1, b1, w2, b2, block_rows=br)
    want = ref.mlp(ref.layer_norm(x, g, b), w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_phi2_pallas_3d_wrapper():
    x = rand(1, (2, 8, 16))
    g, b = jnp.ones(16), jnp.zeros(16)
    w1, b1 = rand(2, (16, 32), 0.2), jnp.zeros(32)
    w2, b2 = rand(3, (32, 16), 0.2), jnp.zeros(16)
    got = mlp.phi2_pallas(x, g, b, w1, b1, w2, b2, block_rows=8)
    want = ref.mlp(ref.layer_norm(x, g, b), w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_ln_zero_mean_unit_var():
    x = rand(1, (4, 64), 5.0)
    z = ref.layer_norm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(jnp.mean(z, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.std(z, -1), 1.0, atol=1e-3)
